//! Minimal benchmark harness (no `criterion` in the offline vendor tree).
//!
//! `bench(name, iters, f)` reports min/mean over iterations after a warmup
//! run; `bench_once` is for expensive end-to-end cases measured once.

use std::time::Instant;

pub struct Bench {
    pub suite: &'static str,
}

impl Bench {
    pub fn new(suite: &'static str) -> Bench {
        println!("=== bench suite: {suite} ===");
        Bench { suite }
    }

    pub fn bench<T>(&self, name: &str, iters: usize, mut f: impl FnMut() -> T) {
        let _ = f(); // warmup
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            let out = f();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
            std::hint::black_box(out);
        }
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        println!(
            "[{}] {name:40} min {min:10.3} ms   mean {mean:10.3} ms   ({iters} iters)",
            self.suite
        );
    }

    pub fn bench_once<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        println!(
            "[{}] {name:40} once {:10.3} ms",
            self.suite,
            t0.elapsed().as_secs_f64() * 1e3
        );
        out
    }
}
