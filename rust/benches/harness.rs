//! Minimal benchmark harness (no `criterion` in the offline vendor tree).
//!
//! `bench(name, iters, f)` reports min/mean over iterations after a
//! warmup run; `bench_flops` additionally derives GFLOP/s from a FLOP
//! count; `bench_once` is for expensive end-to-end cases measured once.
//! Every case is recorded, and `write_json` emits a machine-readable
//! `BENCH_*.json` artifact (per-case min/mean ms and GFLOP/s, plus the
//! GEMM worker count and git revision) for CI and cross-PR comparison.

// Each bench binary uses a subset of the harness API.
#![allow(dead_code)]

use std::cell::RefCell;
use std::time::Instant;

use ficabu::runtime::cpu::gemm;
use ficabu::util::json::Json;

struct Case {
    name: String,
    iters: usize,
    min_ms: f64,
    mean_ms: f64,
    flops: Option<f64>,
    /// Extra numeric fields emitted verbatim into the JSON case (e.g.
    /// `rps`, latency percentiles for the serving bench).
    extras: Vec<(String, f64)>,
}

impl Case {
    fn gflops(&self) -> Option<f64> {
        // flops / (min_ms * 1e-3) / 1e9
        self.flops.map(|fl| fl / (self.min_ms * 1e6))
    }
}

pub struct Bench {
    pub suite: &'static str,
    cases: RefCell<Vec<Case>>,
}

impl Bench {
    pub fn new(suite: &'static str) -> Bench {
        println!("=== bench suite: {suite} ===");
        Bench { suite, cases: RefCell::new(Vec::new()) }
    }

    /// Time `f` over `iters` iterations (after one warmup); returns the
    /// min time in ms.
    pub fn bench<T>(&self, name: &str, iters: usize, f: impl FnMut() -> T) -> f64 {
        self.run_case(name, iters, None, f)
    }

    /// Like [`Bench::bench`], with a FLOP count for GFLOP/s reporting.
    pub fn bench_flops<T>(
        &self,
        name: &str,
        iters: usize,
        flops: f64,
        f: impl FnMut() -> T,
    ) -> f64 {
        self.run_case(name, iters, Some(flops), f)
    }

    fn run_case<T>(
        &self,
        name: &str,
        iters: usize,
        flops: Option<f64>,
        mut f: impl FnMut() -> T,
    ) -> f64 {
        let _ = f(); // warmup
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            let out = f();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
            std::hint::black_box(out);
        }
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let case = Case {
            name: name.to_string(),
            iters,
            min_ms: min,
            mean_ms: mean,
            flops,
            extras: Vec::new(),
        };
        let gf = match case.gflops() {
            Some(g) => format!("   {g:8.2} GFLOP/s"),
            None => String::new(),
        };
        println!(
            "[{}] {name:44} min {min:9.3} ms   mean {mean:9.3} ms{gf}   ({iters} iters)",
            self.suite
        );
        self.cases.borrow_mut().push(case);
        min
    }

    pub fn bench_once<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("[{}] {name:44} once {ms:9.3} ms", self.suite);
        self.cases.borrow_mut().push(Case {
            name: name.to_string(),
            iters: 1,
            min_ms: ms,
            mean_ms: ms,
            flops: None,
            extras: Vec::new(),
        });
        out
    }

    /// Record an externally measured case with extra numeric fields —
    /// used by the serving bench, where a "case" is one whole load-test
    /// arm (min/mean ms = wall / per-request time) annotated with
    /// throughput and latency percentiles.
    pub fn record_case(
        &self,
        name: &str,
        iters: usize,
        min_ms: f64,
        mean_ms: f64,
        extras: &[(&str, f64)],
    ) {
        let mut ex = String::new();
        for (k, v) in extras {
            ex.push_str(&format!("  {k} {v:.2}"));
        }
        println!(
            "[{}] {name:44} min {min_ms:9.3} ms   mean {mean_ms:9.3} ms {ex}",
            self.suite
        );
        self.cases.borrow_mut().push(Case {
            name: name.to_string(),
            iters,
            min_ms,
            mean_ms,
            flops: None,
            extras: extras.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    /// Emit every recorded case as a JSON artifact at `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        let cases: Vec<Json> = self
            .cases
            .borrow()
            .iter()
            .map(|c| {
                let mut fields = vec![
                    ("name", Json::Str(c.name.clone())),
                    ("iters", Json::Num(c.iters as f64)),
                    ("min_ms", Json::Num(c.min_ms)),
                    ("mean_ms", Json::Num(c.mean_ms)),
                    ("gflops", c.gflops().map(Json::Num).unwrap_or(Json::Null)),
                ];
                for (k, v) in &c.extras {
                    fields.push((k.as_str(), Json::Num(*v)));
                }
                Json::obj(fields)
            })
            .collect();
        let root = Json::obj(vec![
            ("suite", Json::Str(self.suite.to_string())),
            ("git_rev", Json::Str(git_rev())),
            ("threads", Json::Num(gemm::effective_threads() as f64)),
            ("cases", Json::Arr(cases)),
        ]);
        std::fs::write(path, format!("{root}\n"))
    }
}

fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| "unknown".to_string())
}
