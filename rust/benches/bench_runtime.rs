//! Runtime/L3 hot-path benches: module dispatch overhead, forward passes,
//! per-segment backward, the full unlearning event, and the patch-GEMM
//! module — the profile that drives the §Perf iteration log.

mod harness;

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::model::{Model, ParamStore};
use ficabu::runtime::{ModuleSpec, Runtime};
use ficabu::tensor::Tensor;
use ficabu::util::prng::Pcg32;
use harness::Bench;

const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

fn main() {
    // artifacts root only hosts the run cache (checkpoints/importance);
    // inventories resolve to the builtins when no export exists
    std::env::set_var("FICABU_ARTIFACTS", ART);
    let b = Bench::new("runtime");
    let rt = Runtime::cpu().unwrap();
    let shared = SharedMeta::builtin();

    // --- dispatch overhead: smallest module (loss_grad) ---
    let meta = ModelMeta::resolve("rn18slim").unwrap();
    let model = Model::load(&rt, meta.clone()).unwrap();
    let mb = meta.microbatch;
    let mut rng = Pcg32::seeded(3);
    let logits = Tensor::new(vec![mb, meta.num_classes],
        rng.normal_vec(mb * meta.num_classes, 1.0)).unwrap();
    let mut onehot = Tensor::zeros(vec![mb, meta.num_classes]);
    for i in 0..mb {
        onehot.data[i * meta.num_classes + i % meta.num_classes] = 1.0;
    }
    b.bench("dispatch: loss_grad module (8x20)", 200, || {
        model.loss_grad(&logits, &onehot).unwrap()
    });

    // --- patch GEMM engine module (256^3) ---
    let gemm = rt.load(&ModuleSpec::Gemm { shared: shared.clone() }).unwrap();
    let d = shared.gemm_demo;
    let x = Tensor::new(vec![d, d], rng.normal_vec(d * d, 1.0)).unwrap();
    let y = Tensor::new(vec![d, d], rng.normal_vec(d * d, 1.0)).unwrap();
    b.bench("patch GEMM module 256x256x256", 50, || {
        gemm.run(&[&x, &y]).unwrap()
    });

    // --- model passes ---
    let params = ParamStore::init(&meta, 5);
    let mut shape = vec![meta.batch];
    shape.extend_from_slice(&meta.input_shape);
    let xin = Tensor::new(shape.clone(), rng.normal_vec(shape.iter().product(), 1.0)).unwrap();
    b.bench("fused logits fwd (B=64, rn18slim)", 10, || {
        model.logits(&params, &xin).unwrap()
    });
    b.bench("cached segment-wise fwd (B=64)", 10, || {
        model.forward_cached(&params, &xin).unwrap()
    });

    // --- end-to-end unlearning event (Table IV inner loop) ---
    let prep = exp::prepare("rn18slim", DatasetKind::Cifar20, &PrepareOpts::default()).unwrap();
    b.bench("unlearning event: FiCABU (early stop)", 5, || {
        exp::run_mode(&prep, 0, Mode::Ficabu, None).unwrap()
    });
    b.bench_once("unlearning event: SSD (all layers)", || {
        exp::run_mode(&prep, 0, Mode::Ssd, None).unwrap()
    });
}
