//! Runtime/L3 hot-path benches: module dispatch overhead, the tiled
//! GEMM core against the retained PR-1 naive kernels on paper-scale
//! layer shapes (ResNet-18 / ViT-Base), the fused conv lowering, forward
//! passes, and the full unlearning event — the profile that drives the
//! §Performance iteration log.
//!
//! Emits `BENCH_runtime.json` at the repo root (per-case min/mean ms,
//! GFLOP/s, thread count, git rev). `FICABU_BENCH_PRESET=smoke` shrinks
//! sizes/iterations for the CI artifact-validity check.

mod harness;

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::model::{Model, ParamStore};
use ficabu::runtime::cpu::gemm;
use ficabu::runtime::cpu::kernels::{self, naive, Conv};
use ficabu::runtime::cpu::scratch::Scratch;
use ficabu::runtime::{ModuleSpec, Runtime};
use ficabu::tensor::quant::QTensor;
use ficabu::tensor::Tensor;
use ficabu::util::prng::Pcg32;
use harness::Bench;

const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
const OUT_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_runtime.json");

fn main() {
    // artifacts root only hosts the run cache (checkpoints/importance);
    // inventories resolve to the builtins when no export exists
    std::env::set_var("FICABU_ARTIFACTS", ART);
    let smoke = matches!(
        std::env::var("FICABU_BENCH_PRESET").as_deref(),
        Ok("smoke")
    );
    let b = Bench::new("runtime");
    println!(
        "[runtime] gemm workers: {} (FICABU_THREADS to override){}",
        gemm::effective_threads(),
        if smoke { "  [smoke preset]" } else { "" }
    );
    let rt = Runtime::cpu().unwrap();
    let shared = SharedMeta::builtin();
    let mut rng = Pcg32::seeded(3);
    let mut sc = Scratch::new();

    // --- tiled GEMM core vs PR-1 naive kernels, paper-scale shapes ---
    // ResNet-18 conv layers as im2col GEMMs (m = b*ho*wo, k = kh*kw*cin,
    // n = cout) and ViT-Base encoder GEMMs (m = tokens).
    let shapes: &[(&str, usize, usize, usize)] = if smoke {
        &[
            ("rn18 conv 16x16x64 (256x576x64)", 256, 576, 64),
            ("vit qkv tiny (64x192x576)", 64, 192, 576),
        ]
    } else {
        &[
            ("rn18 conv2.x 56x56 64ch (3136x576x64)", 3136, 576, 64),
            ("rn18 conv4.x 14x14 256ch (196x2304x256)", 196, 2304, 256),
            ("vit-b qkv (197x768x2304)", 197, 768, 2304),
            ("vit-b mlp-up (197x768x3072)", 197, 768, 3072),
        ]
    };
    let (naive_iters, tiled_iters) = if smoke { (2, 5) } else { (5, 20) };
    for &(name, m, k, n) in shapes {
        let a = rng.normal_vec(m * k, 1.0);
        let bm = rng.normal_vec(k * n, 1.0);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let naive_min = b.bench_flops(&format!("gemm/naive/{name}"), naive_iters, flops, || {
            naive::matmul(&a, &bm, m, k, n)
        });
        let mut out = vec![0.0f32; m * n];
        let tiled_min = b.bench_flops(&format!("gemm/tiled/{name}"), tiled_iters, flops, || {
            gemm::matmul_into(&mut sc, &a, &bm, m, k, n, &mut out);
            out[0]
        });
        println!(
            "[runtime]   -> speedup {:5.2}x over naive ({name})",
            naive_min / tiled_min
        );
        // true-int8 path at the same shape: weight pre-quantized per
        // output channel, activation quantized during panel packing
        let wq = QTensor::from_weight(&Tensor::new(vec![k, n], bm.clone()).unwrap());
        let int8_min = b.bench_flops(&format!("gemm/tiled-int8/{name}"), tiled_iters, flops, || {
            kernels::matmul_i8_into(&mut sc, &a, &wq, m, k, n, &mut out);
            out[0]
        });
        println!(
            "[runtime]   -> int8 speedup {:5.2}x over tiled f32 ({name})",
            tiled_min / int8_min
        );
    }

    // --- conv: fused-packing lowering vs materialized im2col + naive ---
    let cv = Conv { kh: 3, kw: 3, cin: 64, cout: 64, stride: 1 };
    let (cb, ch, cw) = if smoke { (1, 16, 16) } else { (1, 56, 56) };
    let (ho, wo) = cv.out_hw(ch, cw);
    let x = rng.normal_vec(cb * ch * cw * cv.cin, 1.0);
    let wk = rng.normal_vec(cv.kh * cv.kw * cv.cin * cv.cout, 0.5);
    let cflops = 2.0 * (cb * ho * wo) as f64 * (cv.kh * cv.kw * cv.cin) as f64 * cv.cout as f64;
    let conv_name = format!("3x3 {}ch @{}x{}", cv.cin, ch, cw);
    let naive_min = b.bench_flops(&format!("conv/naive/{conv_name}"), naive_iters, cflops, || {
        naive::conv_fwd(&cv, &x, &wk, cb, ch, cw)
    });
    let mut y = vec![0.0f32; cb * ho * wo * cv.cout];
    let fused_min = b.bench_flops(&format!("conv/fused/{conv_name}"), tiled_iters, cflops, || {
        cv.fwd_into(&mut sc, &x, &wk, cb, ch, cw, &mut y);
        y[0]
    });
    println!(
        "[runtime]   -> speedup {:5.2}x over naive (conv {conv_name})",
        naive_min / fused_min
    );
    let wq_conv = QTensor::from_weight(
        &Tensor::new(vec![cv.kh, cv.kw, cv.cin, cv.cout], wk.clone()).unwrap(),
    );
    let int8_conv_min =
        b.bench_flops(&format!("conv/fused-int8/{conv_name}"), tiled_iters, cflops, || {
            cv.fwd_i8_into(&mut sc, &x, &wq_conv, cb, ch, cw, &mut y);
            y[0]
        });
    println!(
        "[runtime]   -> int8 speedup {:5.2}x over fused f32 (conv {conv_name})",
        fused_min / int8_conv_min
    );

    // --- dispatch overhead: smallest module (loss_grad) ---
    let meta = ModelMeta::resolve("rn18slim").unwrap();
    let model = Model::load(&rt, meta.clone()).unwrap();
    let mb = meta.microbatch;
    let logits = Tensor::new(
        vec![mb, meta.num_classes],
        rng.normal_vec(mb * meta.num_classes, 1.0),
    )
    .unwrap();
    let mut onehot = Tensor::zeros(vec![mb, meta.num_classes]);
    for i in 0..mb {
        onehot.data[i * meta.num_classes + i % meta.num_classes] = 1.0;
    }
    b.bench("dispatch: loss_grad module (8x20)", if smoke { 50 } else { 200 }, || {
        model.loss_grad(&logits, &onehot).unwrap()
    });

    // --- patch GEMM engine module (256^3) ---
    let gemm_mod = rt.load(&ModuleSpec::Gemm { shared: shared.clone() }).unwrap();
    let d = shared.gemm_demo;
    let gx = Tensor::new(vec![d, d], rng.normal_vec(d * d, 1.0)).unwrap();
    let gy = Tensor::new(vec![d, d], rng.normal_vec(d * d, 1.0)).unwrap();
    b.bench("patch GEMM module 256x256x256", if smoke { 10 } else { 50 }, || {
        gemm_mod.run(&[&gx, &gy]).unwrap()
    });

    // --- model passes ---
    let params = ParamStore::init(&meta, 5);
    let mut shape = vec![meta.batch];
    shape.extend_from_slice(&meta.input_shape);
    let xin = Tensor::new(shape.clone(), rng.normal_vec(shape.iter().product(), 1.0)).unwrap();
    let pass_iters = if smoke { 2 } else { 10 };
    b.bench("fused logits fwd (B=64, rn18slim)", pass_iters, || {
        model.logits(&params, &xin).unwrap()
    });
    b.bench("cached segment-wise fwd (B=64)", pass_iters, || {
        model.forward_cached(&params, &xin).unwrap()
    });

    // --- end-to-end unlearning event (Table IV inner loop) ---
    let opts = if smoke {
        PrepareOpts { train_steps: 40, importance_batches: 2, ..PrepareOpts::default() }
    } else {
        PrepareOpts::default()
    };
    let prep = exp::prepare("rn18slim", DatasetKind::Cifar20, &opts).unwrap();
    b.bench(
        "unlearning event: FiCABU (early stop)",
        if smoke { 1 } else { 5 },
        || exp::run_mode(&prep, 0, Mode::Ficabu, None).unwrap(),
    );
    if !smoke {
        b.bench_once("unlearning event: SSD (all layers)", || {
            exp::run_mode(&prep, 0, Mode::Ssd, None).unwrap()
        });
        // int8-served pipeline: quantized store, int8 forward/checkpoint
        // GEMMs, f32 gradient chain
        let opts8 = PrepareOpts { int8: true, ..opts };
        let prep8 = exp::prepare("rn18slim", DatasetKind::Cifar20, &opts8).unwrap();
        b.bench("unlearning event: FiCABU int8-served", 5, || {
            exp::run_mode(&prep8, 0, Mode::Ficabu, None).unwrap()
        });
    }

    b.write_json(OUT_JSON).expect("write BENCH_runtime.json");
    println!("[runtime] wrote {OUT_JSON}");
}
