//! Serving-fleet load generator: drives `coordinator::Fleet` at
//! configurable worker counts and offered load, and emits
//! `BENCH_serve.json` (throughput in requests/s plus queue/service
//! latency percentiles per arm) for CI's regression gate.
//!
//! Arms:
//!
//! * `serve/paced/...` — every worker is paced to the *simulated*
//!   FiCABU device latency (`Pacing::SimDevice`, ≥ `FICABU_SERVE_PACE_MS`,
//!   default 4000 ms): each worker stands in for one 50 MHz device, so
//!   throughput measures dispatcher/fleet scaling without the host CPU
//!   as the bottleneck. This is the arm behind the `paced-speedup-4v1`
//!   headline case.
//! * `serve/host/...` — unpaced: workers reply as fast as the host
//!   computes, so scaling here is bounded by host cores.
//! * `serve/coalesce-burst` — one worker, a burst of identical
//!   requests: the dispatcher folds them into ~2 executions with
//!   fan-out replies.
//! * `serve/spec-mix` — the spec-diversity arm: a stream cycling
//!   single-class, multi-class, and sample-level `ForgetSpec`s through
//!   the fleet (host-paced; the single-class paced arms above remain
//!   the regression-gated scaling story).
//! * `serve/http-loopback/workers=2` — the wire path: the same paced
//!   fleet behind the HTTP/1.1 front-end, driven by socket clients over
//!   loopback. Paced like `serve/paced/*`, so it is stable enough to
//!   ride the regression gate.
//! * `serve/http-loopback/parse-lazy` vs `.../parse-tree` — request-body
//!   field extraction: the lazy path scanner (`util::json::scan`) against
//!   the full tree parser on realistic wire bodies. CI's validate step
//!   asserts lazy stays at or below tree.
//! * `serve/chaos-paced/workers=4` — the paced 4-worker arm under a
//!   `testkit::faults` plan injecting engine panics mid-dampen: panicked
//!   requests answer `Failed`, the worker respawns, and CI's validate
//!   step asserts chaos throughput stays at or above half the
//!   fault-free paced arm.
//! * `serve/wal-paced/workers=4` — the paced 4-worker arm with the
//!   durability subsystem on (fsync'd write-ahead ledger + periodic
//!   parameter checkpoints); CI's validate step asserts it keeps ≥ 80%
//!   of the fault-free paced throughput.
//! * `serve/audited-paced/workers=4` — the wal-paced arm with the full
//!   audit pipeline measured: hash-chained `audit.log` appends and the
//!   per-forget MIA attestation probes ride every completion, and the
//!   chain is offline-verified after shutdown. CI's validate step
//!   asserts it keeps ≥ 90% of the fault-free paced throughput.
//! * `serve/multi-tenant/workers=4` — two models (distinct operating
//!   points) behind one registry fleet, mixed load addressed per model.
//!   CI's validate step gates the `graph_builds` extra: compiled graphs
//!   are `Arc`-shared, so builds == models no matter the worker count.
//! * `serve/registry-spinup/workers=4` — wall time of
//!   `Fleet::start_registry` alone: registry workers are O(1) to start
//!   (no per-worker replica build), pinned by `graph_builds_at_start`
//!   staying 0.
//!
//! `FICABU_BENCH_PRESET=smoke` shrinks the request counts for CI.

mod harness;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use ficabu::config::SharedMeta;
use ficabu::coordinator::{
    DurabilityConfig, Fleet, FleetConfig, HttpConfig, HttpServer, ModelId, ModelRegistry, Pacing,
    Reply, WorkerSpec,
};
use ficabu::exp::tables::mode_config;
use ficabu::exp::{self, DatasetKind, Mode, Prepared, PrepareOpts};
use ficabu::runtime::Runtime;
use ficabu::testkit::faults;
use ficabu::unlearn::ForgetSpec;
use ficabu::util::json::{scan, Json};
use harness::Bench;

const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
const OUT_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");

fn pace_floor_ms() -> f64 {
    std::env::var("FICABU_SERVE_PACE_MS")
        .ok()
        .and_then(|s| s.trim().parse::<f64>().ok())
        .unwrap_or(4000.0)
}

fn spec_for(prep: &Prepared, shared: &SharedMeta) -> WorkerSpec {
    WorkerSpec {
        meta: prep.model.meta.clone(),
        shared: shared.clone(),
        params: prep.params.clone(),
        global: prep.global.clone(),
        train: prep.train.clone(),
        cfg: mode_config(prep, Mode::Ficabu, None),
        precision: prep.precision,
    }
}

/// Open-loop burst of `requests` distinct-class requests against a
/// fresh fleet; returns achieved throughput (requests/s).
fn run_arm(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    name: &str,
    workers: usize,
    requests: usize,
    pacing: Pacing,
) -> anyhow::Result<f64> {
    let num_classes = prep.model.meta.num_classes;
    let fleet = Fleet::start(
        spec_for(prep, shared),
        FleetConfig {
            workers,
            queue_cap: requests + 4,
            deadline: None,
            // claim-one passes: even spread across workers, so the arm
            // measures worker scaling, not claim-order luck
            batch_max: 1,
            pacing,
            respawn_giveup: 5,
        },
    )?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| fleet.submit(ForgetSpec::Class(i % num_classes)))
        .collect();
    let mut done = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(_)) => done += 1,
            Ok(other) => anyhow::bail!("{name}: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("{name}: reply channel closed ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = fleet.shutdown()?;
    let total = stats.merged();
    let rps = done as f64 / (wall_ms / 1e3);
    // percentile_fields() is the shared naming authority: these are the
    // same field names `GET /stats` serves and `Summary::to_json` feeds
    let mut extras = vec![("rps", rps), ("workers", workers as f64)];
    extras.extend(total.percentile_fields());
    b.record_case(name, requests, wall_ms, wall_ms / requests as f64, &extras);
    Ok(rps)
}

/// A burst of identical-class requests against one worker: measures
/// coalescing fan-out (k requests, ~2 executions).
fn run_coalesce_burst(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    requests: usize,
) -> anyhow::Result<()> {
    let fleet = Fleet::start(
        spec_for(prep, shared),
        FleetConfig {
            workers: 1,
            queue_cap: requests + 4,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
    )?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests).map(|_| fleet.submit(ForgetSpec::Class(0))).collect();
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(_)) => {}
            Ok(other) => anyhow::bail!("coalesce-burst: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("coalesce-burst: reply channel closed ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = fleet.shutdown()?;
    let total = stats.merged();
    b.record_case(
        "serve/coalesce-burst",
        requests,
        wall_ms,
        wall_ms / requests as f64,
        &[
            ("rps", requests as f64 / (wall_ms / 1e3)),
            ("executions", total.served as f64),
            ("coalesced", stats.coalesced as f64),
        ],
    );
    anyhow::ensure!(
        total.served as usize + stats.coalesced as usize == requests,
        "every burst request must be executed or coalesced"
    );
    Ok(())
}

/// Spec-diversity arm: a request stream cycling all three `ForgetSpec`
/// shapes (single class, 2-class event, 8-sample erasure) through a
/// 2-worker host-paced fleet.
fn run_spec_mix(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    requests: usize,
) -> anyhow::Result<()> {
    let num_classes = prep.model.meta.num_classes;
    let sample_pool = |class: usize| -> Vec<usize> {
        prep.train.class_indices(class).into_iter().take(8).collect()
    };
    let cycle = |i: usize| -> ForgetSpec {
        match i % 3 {
            0 => ForgetSpec::Class(i % num_classes),
            1 => ForgetSpec::Classes(vec![i % num_classes, (i + 7) % num_classes]),
            _ => ForgetSpec::Samples(sample_pool(i % num_classes)),
        }
    };
    let fleet = Fleet::start(
        spec_for(prep, shared),
        FleetConfig {
            workers: 2,
            queue_cap: requests + 4,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
    )?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests).map(|i| fleet.submit(cycle(i))).collect();
    let mut by_kind = [0usize; 3]; // class / classes / samples served
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(sm)) => {
                by_kind[match sm.spec {
                    ForgetSpec::Class(_) => 0,
                    ForgetSpec::Classes(_) => 1,
                    ForgetSpec::Samples(_) => 2,
                }] += 1;
            }
            Ok(other) => anyhow::bail!("spec-mix: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("spec-mix: reply channel closed ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = fleet.shutdown()?;
    let total = stats.merged();
    anyhow::ensure!(
        by_kind.iter().all(|&n| n > 0),
        "spec-mix must serve every spec shape, got {by_kind:?}"
    );
    let mut extras = vec![
        ("rps", requests as f64 / (wall_ms / 1e3)),
        ("workers", 2.0),
        ("class_replies", by_kind[0] as f64),
        ("classes_replies", by_kind[1] as f64),
        ("samples_replies", by_kind[2] as f64),
    ];
    extras.extend(total.percentile_fields());
    b.record_case("serve/spec-mix", requests, wall_ms, wall_ms / requests as f64, &extras);
    println!(
        "[serve] spec-mix: {requests} requests ({} class / {} classes / {} samples replies)",
        by_kind[0], by_kind[1], by_kind[2]
    );
    Ok(())
}

/// Minimal one-shot HTTP client: one connection per request
/// (`Connection: close`); returns the status code and raw body text.
fn http_round(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> anyhow::Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nhost: bench\r\nconnection: close\r\n\
         content-length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut text = String::new();
    s.read_to_string(&mut text)?;
    let status = text
        .split_whitespace()
        .nth(1)
        .and_then(|t| t.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed status line in `{text}`"))?;
    let payload = text.split("\r\n\r\n").nth(1).unwrap_or("").trim().to_string();
    Ok((status, payload))
}

/// Wire arm: the paced fleet behind the HTTP front-end, driven over
/// loopback sockets — one connection per request, `2 * workers` client
/// threads so the fleet (not the socket layer) stays the bottleneck.
fn run_http_arm(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    workers: usize,
    requests: usize,
    pacing: Pacing,
) -> anyhow::Result<()> {
    let num_classes = prep.model.meta.num_classes;
    let fleet = Arc::new(Fleet::start(
        spec_for(prep, shared),
        FleetConfig {
            workers,
            queue_cap: requests + 4,
            deadline: None,
            batch_max: 1,
            pacing,
            respawn_giveup: 5,
        },
    )?);
    let clients = (workers * 2).clamp(1, requests.max(1));
    let srv = HttpServer::bind(
        "127.0.0.1:0",
        Arc::clone(&fleet),
        HttpConfig { threads: clients, ..HttpConfig::default() },
    )?;
    let addr = srv.local_addr();
    let t0 = Instant::now();
    std::thread::scope(|s| -> anyhow::Result<()> {
        let mut joins = Vec::new();
        for c in 0..clients {
            joins.push(s.spawn(move || -> anyhow::Result<()> {
                for i in (c..requests).step_by(clients) {
                    let body = format!(r#"{{"spec": "class:{}"}}"#, i % num_classes);
                    let (status, reply) = http_round(addr, "POST", "/forget", &body)?;
                    anyhow::ensure!(status == 200, "http-loopback: status {status} ({reply})");
                }
                Ok(())
            }));
        }
        for j in joins {
            j.join().expect("client thread")?;
        }
        Ok(())
    })?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    srv.shutdown();
    let fleet = Arc::try_unwrap(fleet).ok().expect("http shutdown releases fleet handles");
    let stats = fleet.shutdown()?;
    let total = stats.merged();
    anyhow::ensure!(
        total.served as usize + stats.coalesced as usize == requests,
        "every wire request must be executed or coalesced"
    );
    let mut extras = vec![
        ("rps", requests as f64 / (wall_ms / 1e3)),
        ("workers", workers as f64),
        ("clients", clients as f64),
    ];
    extras.extend(total.percentile_fields());
    b.record_case(
        &format!("serve/http-loopback/workers={workers}"),
        requests,
        wall_ms,
        wall_ms / requests as f64,
        &extras,
    );
    Ok(())
}

/// Chaos arm: the paced fleet under an injected-panic fault plan.
/// Panicked requests answer `Failed` (unpaced) and cost their worker a
/// respawn; everything else rides the normal paced path. The validate
/// gate asserts chaos throughput ≥ half the fault-free paced arm.
fn run_chaos_arm(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    workers: usize,
    requests: usize,
    pacing: Pacing,
    plan: &str,
) -> anyhow::Result<()> {
    faults::arm(plan)?;
    // Injected panics are the point of this arm: silence the default
    // hook's per-panic backtrace spam for the duration.
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let out = chaos_arm_body(b, prep, shared, workers, requests, pacing, plan);
    std::panic::set_hook(hook);
    faults::clear();
    out
}

fn chaos_arm_body(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    workers: usize,
    requests: usize,
    pacing: Pacing,
    plan: &str,
) -> anyhow::Result<()> {
    let num_classes = prep.model.meta.num_classes;
    let fleet = Fleet::start(
        spec_for(prep, shared),
        FleetConfig {
            workers,
            queue_cap: requests + 4,
            deadline: None,
            batch_max: 1,
            pacing,
            respawn_giveup: 5,
        },
    )?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| fleet.submit(ForgetSpec::Class(i % num_classes)))
        .collect();
    let (mut done, mut failed) = (0usize, 0usize);
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(_)) => done += 1,
            Ok(Reply::Failed(msg)) => {
                anyhow::ensure!(
                    msg.contains("injected fault"),
                    "chaos: unexpected real failure: {msg}"
                );
                failed += 1;
            }
            Ok(other) => anyhow::bail!("chaos: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("chaos: reply channel dropped without an answer ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = fleet.shutdown()?;
    let total = stats.merged();
    anyhow::ensure!(done + failed == requests, "every chaos request is answered");
    anyhow::ensure!(failed >= 1, "plan `{plan}` injected no panic over {requests} requests");
    anyhow::ensure!(done >= 1, "chaos arm must still serve successes");
    anyhow::ensure!(total.respawns >= 1, "a panicked worker must respawn");
    let rps = requests as f64 / (wall_ms / 1e3);
    let mut extras = vec![
        ("rps", rps),
        ("workers", workers as f64),
        ("done", done as f64),
        ("failed", failed as f64),
        ("panics", total.panics as f64),
        ("respawns", total.respawns as f64),
    ];
    extras.extend(total.percentile_fields());
    b.record_case(
        &format!("serve/chaos-paced/workers={workers}"),
        requests,
        wall_ms,
        wall_ms / requests as f64,
        &extras,
    );
    println!(
        "[serve] chaos ({plan}): {done} done / {failed} failed, {} panics, {} respawns",
        total.panics, total.respawns
    );
    Ok(())
}

/// Durability arm: the paced fleet with the write-ahead ledger on — an
/// fsync per admission and completion, plus (single-worker runs only;
/// multi-worker durable fleets never checkpoint) a parameter
/// checkpoint every 8 completions. The validate gate asserts wal-paced
/// throughput stays at or above 80% of the fault-free paced arm:
/// durability must ride the paced envelope, not dominate it.
fn run_wal_arm(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    workers: usize,
    requests: usize,
    pacing: Pacing,
) -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("ficabu_bench_wal_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let num_classes = prep.model.meta.num_classes;
    let fleet = Fleet::start_durable(
        spec_for(prep, shared),
        FleetConfig {
            workers,
            queue_cap: requests + 4,
            deadline: None,
            batch_max: 1,
            pacing,
            respawn_giveup: 5,
        },
        DurabilityConfig { dir: dir.clone(), checkpoint_every: 8 },
    )?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| fleet.submit(ForgetSpec::Class(i % num_classes)))
        .collect();
    let mut done = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(_)) => done += 1,
            Ok(other) => anyhow::bail!("wal-paced: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("wal-paced: reply channel closed ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = fleet.shutdown()?;
    let dur = stats.durability.expect("durable fleet reports durability stats");
    anyhow::ensure!(
        dur.wal_seq as usize == requests,
        "every request gets its own ledger record ({} != {requests})",
        dur.wal_seq
    );
    let total = stats.merged();
    let rps = done as f64 / (wall_ms / 1e3);
    let mut extras = vec![
        ("rps", rps),
        ("workers", workers as f64),
        ("wal_seq", dur.wal_seq as f64),
        ("checkpoints", dur.checkpoints as f64),
    ];
    extras.extend(total.percentile_fields());
    b.record_case(
        &format!("serve/wal-paced/workers={workers}"),
        requests,
        wall_ms,
        wall_ms / requests as f64,
        &extras,
    );
    println!(
        "[serve] wal-paced: {done} done, ledger seq {} / {} checkpoint(s)",
        dur.wal_seq, dur.checkpoints
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

/// Audited-durability arm: identical load to `run_wal_arm`, but the
/// case is gated on the *audit* cost riding every completion — the MIA
/// attestation probes in the engine, the hash-chained `audit.log`
/// append under the pair lock, and (after shutdown) a full offline
/// chain verification. `attested` counts links carrying evidence;
/// `chain_len` is the verified chain length.
fn run_audited_arm(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    workers: usize,
    requests: usize,
    pacing: Pacing,
) -> anyhow::Result<()> {
    let dir =
        std::env::temp_dir().join(format!("ficabu_bench_audit_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let num_classes = prep.model.meta.num_classes;
    let fleet = Fleet::start_durable(
        spec_for(prep, shared),
        FleetConfig {
            workers,
            queue_cap: requests + 4,
            deadline: None,
            batch_max: 1,
            pacing,
            respawn_giveup: 5,
        },
        DurabilityConfig { dir: dir.clone(), checkpoint_every: 8 },
    )?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| fleet.submit(ForgetSpec::Class(i % num_classes)))
        .collect();
    let mut done = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(_)) => done += 1,
            Ok(other) => anyhow::bail!("audited-paced: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("audited-paced: reply channel closed ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let chain = fleet.audit_chain(&ModelId::default());
    let stats = fleet.shutdown()?;
    let report = ficabu::audit::verify_dir(&dir)?;
    // Identical specs coalesce into one execution (one link answering
    // several requests), so the chain may be shorter than the request
    // count — but never empty, and disk must agree with memory.
    anyhow::ensure!(
        !report.records.is_empty() && chain.len() == report.records.len(),
        "every completed execution appends one verifiable chain link \
         ({} on disk, {} in memory, {requests} requests)",
        report.records.len(),
        chain.len()
    );
    let attested = report.records.iter().filter(|r| r.attest.is_some()).count();
    anyhow::ensure!(
        attested == report.records.len(),
        "real engine executions always attest ({attested} of {})",
        report.records.len()
    );
    let total = stats.merged();
    let rps = done as f64 / (wall_ms / 1e3);
    let mut extras = vec![
        ("rps", rps),
        ("workers", workers as f64),
        ("attested", attested as f64),
        ("chain_len", report.records.len() as f64),
    ];
    extras.extend(total.percentile_fields());
    b.record_case(
        &format!("serve/audited-paced/workers={workers}"),
        requests,
        wall_ms,
        wall_ms / requests as f64,
        &extras,
    );
    println!(
        "[serve] audited-paced: {done} done, chain {} link(s), {attested} attested, verified",
        report.records.len()
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

/// Multi-tenant arm: two models with distinct operating points behind
/// one registry fleet, driven with a mixed, model-addressed load. Two
/// cases come out of one run:
///
/// * `serve/registry-spinup/workers=N` — wall time of
///   `Fleet::start_registry` alone. Registry workers are O(1): they
///   borrow `Arc`-shared compiled graphs instead of building replicas,
///   so spin-up compiles nothing (`graph_builds_at_start` stays 0).
/// * `serve/multi-tenant/workers=N` — paced throughput of the mixed
///   load, with the shared-build counter as the `graph_builds` extra:
///   graphs compile once per model per process, never per worker.
fn run_multi_tenant_arm(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    workers: usize,
    requests: usize,
    pacing: Pacing,
) -> anyhow::Result<()> {
    let num_classes = prep.model.meta.num_classes;
    let tenant_a = ModelId::new("tenant-a")?;
    let tenant_b = ModelId::new("tenant-b")?;
    let reg = ModelRegistry::new(Runtime::cpu()?);
    reg.register(tenant_a.clone(), spec_for(prep, shared))?;
    // Same master, different operating point: tenant-b doubles the
    // dampening strength, so the tenants never share a batch key.
    let mut spec_b = spec_for(prep, shared);
    spec_b.cfg.alpha *= 2.0;
    reg.register(tenant_b.clone(), spec_b)?;
    let reg = Arc::new(reg);

    let t_up = Instant::now();
    let fleet = Fleet::start_registry(
        Arc::clone(&reg),
        FleetConfig {
            workers,
            queue_cap: requests + 4,
            deadline: None,
            batch_max: 1,
            pacing,
            respawn_giveup: 5,
        },
    )?;
    let spinup_ms = t_up.elapsed().as_secs_f64() * 1e3;
    let builds_at_start = reg.builds();
    anyhow::ensure!(
        builds_at_start == 0,
        "registry worker spin-up must not compile graphs ({builds_at_start} builds)"
    );
    b.record_case(
        &format!("serve/registry-spinup/workers={workers}"),
        workers,
        spinup_ms,
        spinup_ms / workers as f64,
        &[
            ("workers", workers as f64),
            ("graph_builds_at_start", builds_at_start as f64),
        ],
    );

    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            let model = if i % 2 == 0 { tenant_a.clone() } else { tenant_b.clone() };
            fleet.submit_to(model, ForgetSpec::Class(i % num_classes), None)
        })
        .collect();
    let mut done = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(_)) => done += 1,
            Ok(other) => anyhow::bail!("multi-tenant: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("multi-tenant: reply channel closed ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = fleet.shutdown()?;
    anyhow::ensure!(
        stats.per_model.len() == 2,
        "both tenants must be served, got {} rollup rows",
        stats.per_model.len()
    );
    let builds = reg.builds();
    anyhow::ensure!(
        builds == 2,
        "graphs compile once per model, not per worker ({builds} builds for 2 models)"
    );
    let total = stats.merged();
    let rps = done as f64 / (wall_ms / 1e3);
    let mut extras = vec![
        ("rps", rps),
        ("workers", workers as f64),
        ("models", 2.0),
        ("graph_builds", builds as f64),
        ("spinup_ms", spinup_ms),
    ];
    extras.extend(total.percentile_fields());
    b.record_case(
        &format!("serve/multi-tenant/workers={workers}"),
        requests,
        wall_ms,
        wall_ms / requests as f64,
        &extras,
    );
    println!(
        "[serve] multi-tenant: {done} done across 2 models, {builds} graph builds, \
         spin-up {spinup_ms:.1} ms"
    );
    Ok(())
}

/// Request-body field extraction micro-arms: the lazy path scanner vs
/// the full tree parser over a batch of realistic wire bodies (control
/// fields first, then a bulky telemetry payload the admission path
/// never needs — exactly what laziness skips).
fn run_parse_arms(b: &Bench) {
    let bodies: Vec<String> = (0..256)
        .map(|i| {
            let trace: Vec<String> =
                (0..48).map(|t| ((i * 31 + t * 7) % 1000).to_string()).collect();
            format!(
                r#"{{"spec": "classes:{},{}", "deadline_ms": {}, "client": "edge-{:03}", "trace": [{}]}}"#,
                i % 10,
                (i + 3) % 10,
                50 + (i % 200),
                i,
                trace.join(",")
            )
        })
        .collect();
    let iters = 40;
    let lazy_ms = b.bench("serve/http-loopback/parse-lazy", iters, || {
        let mut sum = 0.0;
        for body in &bodies {
            let spec = scan::path(body, &["spec"]).unwrap().unwrap();
            sum += spec.text().len() as f64;
            sum += scan::path_f64(body, &["deadline_ms"]).unwrap().unwrap();
        }
        sum
    });
    let tree_ms = b.bench("serve/http-loopback/parse-tree", iters, || {
        let mut sum = 0.0;
        for body in &bodies {
            let j = Json::parse(body).unwrap();
            sum += j.get("spec").unwrap().as_str().unwrap().len() as f64;
            sum += j.get("deadline_ms").unwrap().as_f64().unwrap();
        }
        sum
    });
    println!(
        "[serve] lazy path scan vs full tree parse: {:.1}x",
        tree_ms / lazy_ms.max(1e-9)
    );
}

fn main() -> anyhow::Result<()> {
    // artifacts root hosts the run cache (checkpoint + importance);
    // inventories resolve to the builtins
    std::env::set_var("FICABU_ARTIFACTS", ART);
    let smoke = matches!(
        std::env::var("FICABU_BENCH_PRESET").as_deref(),
        Ok("smoke")
    );
    let b = Bench::new("serve");
    let floor = pace_floor_ms();
    println!(
        "[serve] pace floor {floor:.0} ms (FICABU_SERVE_PACE_MS){}",
        if smoke { "  [smoke preset]" } else { "" }
    );

    // PinsFace: the high-similarity task with aggressive early stop —
    // the paper's bursty forget-request deployment story.
    let opts = if smoke {
        PrepareOpts { train_steps: 24, importance_batches: 1, ..Default::default() }
    } else {
        PrepareOpts::default()
    };
    let prep = b.bench_once("prepare rn18slim/pinsface", || {
        exp::prepare("rn18slim", DatasetKind::PinsFace, &opts)
    })?;
    let shared = SharedMeta::resolve()?;

    // --- paced arms: fleet scaling with one simulated device per worker
    let paced = Pacing::SimDevice { floor_ms: floor };
    let paced_requests = if smoke { 8 } else { 16 };
    let worker_arms: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let mut paced_rps = Vec::new();
    for &w in worker_arms {
        let rps = run_arm(
            &b,
            &prep,
            &shared,
            &format!("serve/paced/workers={w}"),
            w,
            paced_requests,
            paced,
        )?;
        paced_rps.push((w, rps));
    }
    let rps_of = |w: usize| paced_rps.iter().find(|(x, _)| *x == w).map(|(_, r)| *r);
    let rps1 = rps_of(1).unwrap_or(0.0);
    let rps4 = rps_of(4).unwrap_or(0.0);
    if rps1 > 0.0 && rps4 > 0.0 {
        let speedup = rps4 / rps1;
        b.record_case(
            "serve/paced-speedup-4v1",
            1,
            0.0,
            0.0,
            &[("speedup", speedup), ("rps_1w", rps1), ("rps_4w", rps4)],
        );
        println!("[serve] paced 4-worker speedup over 1 worker: {speedup:.2}x");
    }

    // --- host-bound arms: real host scaling (core-count limited)
    let host_requests = if smoke { 4 } else { 8 };
    for &w in &[1usize, 4] {
        run_arm(
            &b,
            &prep,
            &shared,
            &format!("serve/host/workers={w}"),
            w,
            host_requests,
            Pacing::Host,
        )?;
    }

    // --- duplicate-burst coalescing
    run_coalesce_burst(&b, &prep, &shared, if smoke { 16 } else { 32 })?;

    // --- spec-diversity arm (ForgetSpec grammar through the fleet)
    run_spec_mix(&b, &prep, &shared, if smoke { 6 } else { 12 })?;

    // --- wire path: paced fleet behind the HTTP front-end over loopback
    run_http_arm(&b, &prep, &shared, 2, if smoke { 6 } else { 12 }, paced)?;

    // --- chaos arm: the paced 4-worker fleet under injected panics.
    // One-shot Nth triggers, not `everyN`: requests run a data-dependent
    // number of dampen depths, so a periodic trigger could in principle
    // panic every request; fixed hit counts keep the failed/done split
    // deterministic (each pass hits `dampen` at least once, so with
    // `requests` >= the largest N every trigger is guaranteed to fire).
    let chaos_plan = if smoke { "dampen:2:panic" } else { "dampen:3:panic;dampen:11:panic" };
    run_chaos_arm(&b, &prep, &shared, 4, paced_requests, paced, chaos_plan)?;

    // --- durability arm: the same paced 4-worker fleet, ledger on
    run_wal_arm(&b, &prep, &shared, 4, paced_requests, paced)?;

    // --- audited arm: ledger + hash-chained audit log + MIA attestation
    run_audited_arm(&b, &prep, &shared, 4, paced_requests, paced)?;

    // --- multi-tenant arm: two models behind one registry fleet, plus
    // the registry worker spin-up case
    run_multi_tenant_arm(&b, &prep, &shared, 4, paced_requests, paced)?;

    // --- request-body parsing: lazy path scan vs full tree parse
    run_parse_arms(&b);

    b.write_json(OUT_JSON)?;
    println!("wrote {OUT_JSON}");
    Ok(())
}
