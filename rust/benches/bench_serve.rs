//! Serving-fleet load generator: drives `coordinator::Fleet` at
//! configurable worker counts and offered load, and emits
//! `BENCH_serve.json` (throughput in requests/s plus queue/service
//! latency percentiles per arm) for CI's regression gate.
//!
//! Arms:
//!
//! * `serve/paced/...` — every worker is paced to the *simulated*
//!   FiCABU device latency (`Pacing::SimDevice`, ≥ `FICABU_SERVE_PACE_MS`,
//!   default 4000 ms): each worker stands in for one 50 MHz device, so
//!   throughput measures dispatcher/fleet scaling without the host CPU
//!   as the bottleneck. This is the arm behind the `paced-speedup-4v1`
//!   headline case.
//! * `serve/host/...` — unpaced: workers reply as fast as the host
//!   computes, so scaling here is bounded by host cores.
//! * `serve/coalesce-burst` — one worker, a burst of identical
//!   requests: the dispatcher folds them into ~2 executions with
//!   fan-out replies.
//! * `serve/spec-mix` — the spec-diversity arm: a stream cycling
//!   single-class, multi-class, and sample-level `ForgetSpec`s through
//!   the fleet (host-paced; the single-class paced arms above remain
//!   the regression-gated scaling story).
//!
//! `FICABU_BENCH_PRESET=smoke` shrinks the request counts for CI.

mod harness;

use std::time::Instant;

use ficabu::config::SharedMeta;
use ficabu::coordinator::{Fleet, FleetConfig, Pacing, Reply, WorkerSpec};
use ficabu::exp::tables::mode_config;
use ficabu::exp::{self, DatasetKind, Mode, Prepared, PrepareOpts};
use ficabu::unlearn::ForgetSpec;
use harness::Bench;

const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");
const OUT_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");

fn pace_floor_ms() -> f64 {
    std::env::var("FICABU_SERVE_PACE_MS")
        .ok()
        .and_then(|s| s.trim().parse::<f64>().ok())
        .unwrap_or(4000.0)
}

fn spec_for(prep: &Prepared, shared: &SharedMeta) -> WorkerSpec {
    WorkerSpec {
        meta: prep.model.meta.clone(),
        shared: shared.clone(),
        params: prep.params.clone(),
        global: prep.global.clone(),
        train: prep.train.clone(),
        cfg: mode_config(prep, Mode::Ficabu, None),
        precision: prep.precision,
    }
}

/// Open-loop burst of `requests` distinct-class requests against a
/// fresh fleet; returns achieved throughput (requests/s).
fn run_arm(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    name: &str,
    workers: usize,
    requests: usize,
    pacing: Pacing,
) -> anyhow::Result<f64> {
    let num_classes = prep.model.meta.num_classes;
    let fleet = Fleet::start(
        spec_for(prep, shared),
        FleetConfig {
            workers,
            queue_cap: requests + 4,
            deadline: None,
            // claim-one passes: even spread across workers, so the arm
            // measures worker scaling, not claim-order luck
            batch_max: 1,
            pacing,
        },
    )?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| fleet.submit(ForgetSpec::Class(i % num_classes)))
        .collect();
    let mut done = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(_)) => done += 1,
            Ok(other) => anyhow::bail!("{name}: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("{name}: reply channel closed ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = fleet.shutdown()?;
    let total = stats.merged();
    let rps = done as f64 / (wall_ms / 1e3);
    b.record_case(
        name,
        requests,
        wall_ms,
        wall_ms / requests as f64,
        &[
            ("rps", rps),
            ("workers", workers as f64),
            ("queue_p50_ms", total.queue_hist.p50_ms()),
            ("queue_p99_ms", total.queue_hist.p99_ms()),
            ("service_p50_ms", total.service_hist.p50_ms()),
            ("service_p99_ms", total.service_hist.p99_ms()),
        ],
    );
    Ok(rps)
}

/// A burst of identical-class requests against one worker: measures
/// coalescing fan-out (k requests, ~2 executions).
fn run_coalesce_burst(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    requests: usize,
) -> anyhow::Result<()> {
    let fleet = Fleet::start(
        spec_for(prep, shared),
        FleetConfig {
            workers: 1,
            queue_cap: requests + 4,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
        },
    )?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests).map(|_| fleet.submit(ForgetSpec::Class(0))).collect();
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(_)) => {}
            Ok(other) => anyhow::bail!("coalesce-burst: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("coalesce-burst: reply channel closed ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = fleet.shutdown()?;
    let total = stats.merged();
    b.record_case(
        "serve/coalesce-burst",
        requests,
        wall_ms,
        wall_ms / requests as f64,
        &[
            ("rps", requests as f64 / (wall_ms / 1e3)),
            ("executions", total.served as f64),
            ("coalesced", stats.coalesced as f64),
        ],
    );
    anyhow::ensure!(
        total.served as usize + stats.coalesced as usize == requests,
        "every burst request must be executed or coalesced"
    );
    Ok(())
}

/// Spec-diversity arm: a request stream cycling all three `ForgetSpec`
/// shapes (single class, 2-class event, 8-sample erasure) through a
/// 2-worker host-paced fleet.
fn run_spec_mix(
    b: &Bench,
    prep: &Prepared,
    shared: &SharedMeta,
    requests: usize,
) -> anyhow::Result<()> {
    let num_classes = prep.model.meta.num_classes;
    let sample_pool = |class: usize| -> Vec<usize> {
        prep.train.class_indices(class).into_iter().take(8).collect()
    };
    let cycle = |i: usize| -> ForgetSpec {
        match i % 3 {
            0 => ForgetSpec::Class(i % num_classes),
            1 => ForgetSpec::Classes(vec![i % num_classes, (i + 7) % num_classes]),
            _ => ForgetSpec::Samples(sample_pool(i % num_classes)),
        }
    };
    let fleet = Fleet::start(
        spec_for(prep, shared),
        FleetConfig {
            workers: 2,
            queue_cap: requests + 4,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
        },
    )?;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests).map(|i| fleet.submit(cycle(i))).collect();
    let mut by_kind = [0usize; 3]; // class / classes / samples served
    for rx in rxs {
        match rx.recv() {
            Ok(Reply::Done(sm)) => {
                by_kind[match sm.spec {
                    ForgetSpec::Class(_) => 0,
                    ForgetSpec::Classes(_) => 1,
                    ForgetSpec::Samples(_) => 2,
                }] += 1;
            }
            Ok(other) => anyhow::bail!("spec-mix: unexpected reply {other:?}"),
            Err(e) => anyhow::bail!("spec-mix: reply channel closed ({e})"),
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = fleet.shutdown()?;
    let total = stats.merged();
    anyhow::ensure!(
        by_kind.iter().all(|&n| n > 0),
        "spec-mix must serve every spec shape, got {by_kind:?}"
    );
    b.record_case(
        "serve/spec-mix",
        requests,
        wall_ms,
        wall_ms / requests as f64,
        &[
            ("rps", requests as f64 / (wall_ms / 1e3)),
            ("workers", 2.0),
            ("class_replies", by_kind[0] as f64),
            ("classes_replies", by_kind[1] as f64),
            ("samples_replies", by_kind[2] as f64),
            ("service_p50_ms", total.service_hist.p50_ms()),
            ("service_p99_ms", total.service_hist.p99_ms()),
        ],
    );
    println!(
        "[serve] spec-mix: {requests} requests ({} class / {} classes / {} samples replies)",
        by_kind[0], by_kind[1], by_kind[2]
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // artifacts root hosts the run cache (checkpoint + importance);
    // inventories resolve to the builtins
    std::env::set_var("FICABU_ARTIFACTS", ART);
    let smoke = matches!(
        std::env::var("FICABU_BENCH_PRESET").as_deref(),
        Ok("smoke")
    );
    let b = Bench::new("serve");
    let floor = pace_floor_ms();
    println!(
        "[serve] pace floor {floor:.0} ms (FICABU_SERVE_PACE_MS){}",
        if smoke { "  [smoke preset]" } else { "" }
    );

    // PinsFace: the high-similarity task with aggressive early stop —
    // the paper's bursty forget-request deployment story.
    let opts = if smoke {
        PrepareOpts { train_steps: 24, importance_batches: 1, ..Default::default() }
    } else {
        PrepareOpts::default()
    };
    let prep = b.bench_once("prepare rn18slim/pinsface", || {
        exp::prepare("rn18slim", DatasetKind::PinsFace, &opts)
    })?;
    let shared = SharedMeta::resolve()?;

    // --- paced arms: fleet scaling with one simulated device per worker
    let paced = Pacing::SimDevice { floor_ms: floor };
    let paced_requests = if smoke { 8 } else { 16 };
    let worker_arms: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let mut paced_rps = Vec::new();
    for &w in worker_arms {
        let rps = run_arm(
            &b,
            &prep,
            &shared,
            &format!("serve/paced/workers={w}"),
            w,
            paced_requests,
            paced,
        )?;
        paced_rps.push((w, rps));
    }
    let rps_of = |w: usize| paced_rps.iter().find(|(x, _)| *x == w).map(|(_, r)| *r);
    let rps1 = rps_of(1).unwrap_or(0.0);
    let rps4 = rps_of(4).unwrap_or(0.0);
    if rps1 > 0.0 && rps4 > 0.0 {
        let speedup = rps4 / rps1;
        b.record_case(
            "serve/paced-speedup-4v1",
            1,
            0.0,
            0.0,
            &[("speedup", speedup), ("rps_1w", rps1), ("rps_4w", rps4)],
        );
        println!("[serve] paced 4-worker speedup over 1 worker: {speedup:.2}x");
    }

    // --- host-bound arms: real host scaling (core-count limited)
    let host_requests = if smoke { 4 } else { 8 };
    for &w in &[1usize, 4] {
        run_arm(
            &b,
            &prep,
            &shared,
            &format!("serve/host/workers={w}"),
            w,
            host_requests,
            Pacing::Host,
        )?;
    }

    // --- duplicate-burst coalescing
    run_coalesce_burst(&b, &prep, &shared, if smoke { 16 } else { 32 })?;

    // --- spec-diversity arm (ForgetSpec grammar through the fleet)
    run_spec_mix(&b, &prep, &shared, if smoke { 6 } else { 12 })?;

    b.write_json(OUT_JSON)?;
    println!("wrote {OUT_JSON}");
    Ok(())
}
