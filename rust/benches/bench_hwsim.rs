//! Hardware-model benches: the IP speedups the paper reports (11.7x FIMD,
//! 7.9x Dampening), the pipeline-overlap property of Fig. 5c, and the
//! live FIMD/Dampening engine throughput (compiled Pallas modules).

mod harness;

use ficabu::config::SharedMeta;
use ficabu::fisher::FimdEngine;
use ficabu::hwsim::ip::StreamingIp;
use ficabu::hwsim::mem::Precision;
use ficabu::hwsim::FicabuProcessor;
use ficabu::runtime::Runtime;
use ficabu::unlearn::DampEngine;
use ficabu::util::prng::Pcg32;
use harness::Bench;

const ART: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

fn main() {
    std::env::set_var("FICABU_ARTIFACTS", ART);
    let b = Bench::new("hwsim");

    // --- modelled IP speedups (paper §IV-A numbers) ---
    for (ip, paper) in [
        (StreamingIp::fimd(8192), 11.7),
        (StreamingIp::dampening(8192), 7.9),
    ] {
        let elems = 1u64 << 22;
        let s = ip.speedup(elems);
        println!(
            "[hwsim] {:4} IP vs core: modelled speedup {s:.2}x (paper {paper}x) over {elems} elems",
            ip.name
        );
        assert!((s - paper).abs() < 0.2);
    }

    // --- pipeline overlap: cadence equals GEMM window ---
    let proc_ = FicabuProcessor::new(8192, Precision::Int8);
    let ev = proc_.trace(32, [64, 24, 16]);
    let gemm: Vec<_> = ev.iter().filter(|e| e.0 == 0).collect();
    let cadence = gemm[1].2 - gemm[0].2;
    println!("[hwsim] pipeline cadence {cadence} cycles (= GEMM patch window 64)");
    assert_eq!(cadence, 64);

    // --- live engine throughput (reference kernel tiles) ---
    let rt = Runtime::cpu().unwrap();
    let shared = SharedMeta::builtin();
    let fimd = FimdEngine::new(&rt, &shared).unwrap();
    let damp = DampEngine::new(&rt, &shared).unwrap();
    let mut rng = Pcg32::seeded(1);
    let n = shared.tile * 8;
    let grads = rng.normal_vec(n, 0.1);
    let mut acc = vec![0.0f32; n];
    b.bench("fimd engine: 8 tiles (64K elems)", 20, || {
        fimd.accumulate(&mut acc, &grads, 0.125).unwrap();
    });
    let idf: Vec<f32> = rng.normal_vec(n, 1.0).iter().map(|v| v.abs()).collect();
    let idd = vec![1.0f32; n];
    let mut theta = rng.normal_vec(n, 1.0);
    b.bench("dampening engine: 8 tiles (64K elems)", 20, || {
        damp.dampen(&mut theta, &idf, &idd, 10.0, 1.0).unwrap();
    });

    // throughput summary
    let elems_per_pass = n as f64;
    println!(
        "[hwsim] streamed {:.0} elems/pass through each engine module",
        elems_per_pass
    );
}
