//! Table benches: one end-to-end measurement per paper table/figure
//! (DESIGN.md §4 index). Each bench regenerates the table's core quantity
//! and asserts the paper's qualitative shape, timing the run.
//!
//! T1 (CAU MACs reduction), T2 (BD RPR), T4 (INT8 + ES), F3 (selection
//! distribution), F4 (S(l) profile). Table III / Fig 5c are covered by
//! bench_hwsim + power_report.

mod harness;

use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::hwsim::mem::Precision;
use ficabu::metrics::rpr::rpr;
use ficabu::unlearn::Schedule;
use harness::Bench;

fn main() {
    // cargo runs bench executables with cwd = package root (rust/)
    std::env::set_var(
        "FICABU_ARTIFACTS",
        concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts"),
    );
    let b = Bench::new("tables");
    let opts = PrepareOpts::default();
    let prep = b.bench_once("prepare rn18slim/cifar20 (cached)", || {
        exp::prepare("rn18slim", DatasetKind::Cifar20, &opts).unwrap()
    });

    // --- Table I: CAU vs SSD ---
    let (ssd, cau) = b.bench_once("T1: SSD + CAU on one class", || {
        let ssd = exp::run_mode(&prep, 0, Mode::Ssd, None).unwrap();
        let cau = exp::run_mode(&prep, 0, Mode::Cau, None).unwrap();
        (ssd, cau)
    });
    println!(
        "[tables] T1 shape: CAU Df {:.1}% (tau {:.0}%), editing MACs {:.3}% of SSD",
        100.0 * cau.df,
        100.0 * prep.kind.tau(),
        cau.macs_vs_ssd_pct
    );
    assert!(cau.df <= prep.kind.tau() + 1e-9);
    assert!(cau.macs_vs_ssd_pct < 50.0, "CAU must cut editing MACs");

    // --- Table II: BD RPR ---
    let bd = b.bench_once("T2: BD on one class", || {
        let sel = ssd.report.as_ref().unwrap().selected_per_depth.clone();
        exp::run_mode(&prep, 0, Mode::Bd, Some(&sel)).unwrap()
    });
    let base = exp::run_mode(&prep, 0, Mode::Baseline, None).unwrap();
    let r = rpr(base.dr, ssd.dr, bd.dr);
    println!(
        "[tables] T2 shape: BD Df {:.1}%, dDr SSD {:.2}pp vs BD {:.2}pp, RPR {r:+.1}",
        100.0 * bd.df,
        100.0 * (base.dr - ssd.dr),
        100.0 * (base.dr - bd.dr)
    );
    assert!(bd.df <= prep.kind.tau() + 1e-9, "BD must still forget");
    assert!(bd.dr >= ssd.dr - 1e-9, "BD must preserve at least as much retain accuracy");

    // --- Table IV: combined engine + hw energy ---
    let (es, macs) = b.bench_once("T4: FiCABU vs SSD-on-baseline (INT8 hw model)", || {
        let sel = ssd.report.as_ref().unwrap().selected_per_depth.clone();
        let fic = exp::run_mode(&prep, 0, Mode::Ficabu, Some(&sel)).unwrap();
        let (_, _, es) = exp::tables::hardware_cost(
            &prep,
            fic.report.as_ref().unwrap(),
            ssd.report.as_ref().unwrap(),
            Precision::Int8,
        );
        (es, fic.macs_vs_ssd_pct)
    });
    println!("[tables] T4 shape: ES {:.2}% (paper 93.52% CIFAR-20), MACs {macs:.3}%", 100.0 * es);
    assert!(es > 0.5, "FiCABU must save the majority of energy");

    // --- Fig 3: back-end concentration ---
    let sel = &ssd.report.as_ref().unwrap().selected_per_depth;
    let meta = &prep.model.meta;
    let share = |l: usize| {
        sel[l - 1] as f64 / meta.segments[meta.seg_index(l)].param_count().max(1) as f64
    };
    let back = (share(1) + share(2)) / 2.0;
    let front = (share(meta.num_segments()) + share(meta.num_segments() - 1)) / 2.0;
    println!("[tables] F3 shape: back-end selection share {back:.4} vs front-end {front:.4}");
    assert!(back > front, "selection must concentrate toward the back-end");

    // --- Fig 4: S(l) profile from this selection ---
    let sched = Schedule::from_selection_distribution(sel, 10.0);
    let prof = sched.profile(meta.num_segments());
    println!("[tables] F4 shape: S(1) = {:.2} ... S(L) = {:.2}", prof[0], prof[prof.len() - 1]);
    assert!((prof[0] - 1.0).abs() < 1e-9 && (prof[prof.len() - 1] - 10.0).abs() < 1e-9);

    println!("[tables] all table shapes hold");
}
