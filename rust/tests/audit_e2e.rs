//! Audit-chain end-to-end tests: real durable fleets driving the real
//! engine, with the hash-chained `audit.log` verified offline after the
//! fact.
//!
//! Covers the verifiable-unlearning guarantees: a multi-forget run
//! produces a chain `audit verify` accepts (heads, checkpoint anchors,
//! per-link MIA attestation); any single-byte mutation of `audit.log` —
//! CRC damage or a CRC-valid forged record — is rejected naming the
//! offending record; kill-and-restart recovery re-enters the chain
//! deterministically (identical per-link core hashes to an
//! uninterrupted run); and a failed audit append taints the in-memory
//! link without blocking the caller's reply.
//!
//! The fault plan is process-global, so every test here serializes on
//! one lock and clears the plan before releasing it.

use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use ficabu::audit::{self, AuditRecord};
use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::coordinator::{
    wal, DurabilityConfig, Fleet, FleetConfig, ModelId, Pacing, Reply, Summary, WorkerSpec,
};
use ficabu::data::{cifar20_like, Dataset, DatasetCfg};
use ficabu::fisher::Importance;
use ficabu::model::ParamStore;
use ficabu::runtime::Precision;
use ficabu::testkit::faults;
use ficabu::unlearn::{ForgetSpec, Ssd};

static AUDIT: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    AUDIT.lock().unwrap_or_else(PoisonError::into_inner)
}

fn train_set() -> Dataset {
    let cfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
    cifar20_like(&cfg).0
}

fn durable_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ficabu_audit_e2e_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn durable_wspec(seed: u64) -> WorkerSpec {
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    WorkerSpec {
        meta: meta.clone(),
        shared: SharedMeta::builtin(),
        params: ParamStore::init(&meta, seed),
        global,
        train: train_set(),
        cfg: Ssd::new(1.0, 1.0).into_config(),
        precision: Precision::F32,
    }
}

/// One-worker durable production fleet, checkpointing every completion —
/// the configuration under which chains, anchors, and replay identity
/// are all exercised.
fn durable_fleet(dir: &Path) -> Fleet {
    Fleet::start_durable(
        durable_wspec(5),
        FleetConfig {
            workers: 1,
            queue_cap: 8,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
        DurabilityConfig { dir: dir.to_path_buf(), checkpoint_every: 1 },
    )
    .unwrap()
}

/// Replayed entries have no reply channel; poll the rollup instead.
fn wait_served(fleet: &Fleet, n: u64) {
    let t0 = Instant::now();
    while fleet.stats().merged().served < n {
        assert!(t0.elapsed() < Duration::from_secs(120), "replayed work never completed");
        std::thread::sleep(Duration::from_millis(25));
    }
}

fn submit_done(fleet: &Fleet, spec: ForgetSpec) -> Summary {
    match fleet.submit(spec.clone()).recv().unwrap() {
        Reply::Done(sm) => sm,
        other => panic!("{spec}: unexpected reply {other:?}"),
    }
}

/// The headline chain guarantee: three completed forgets produce a
/// chain `verify_dir` accepts — linked hashes, one head anchored by the
/// checkpoint, and a well-formed MIA attestation embedded per link.
/// (The *directional* member-rate drop needs a trained model and lives
/// in `tests/audit_attest_e2e.rs`; this untrained fixture keeps the
/// chain mechanics fast.)
#[test]
fn three_forget_chain_verifies_with_attestation() {
    let _g = serial();
    faults::clear();
    let dir = durable_dir("three");

    {
        let fleet = durable_fleet(&dir);
        for class in [1usize, 2, 5] {
            let sm = submit_done(&fleet, ForgetSpec::Class(class));
            let at = sm.attest.as_ref().expect("every real forget carries an attestation");
            assert!(
                (0.0..=1.0).contains(&at.mia_before) && (0.0..=1.0).contains(&at.mia_after),
                "class {class}: member-rates are probabilities, got {} -> {}",
                at.mia_before,
                at.mia_after
            );
        }
        fleet.shutdown().unwrap();
    }

    let report = audit::verify_dir(&dir).unwrap();
    assert_eq!(report.records.len(), 3);
    assert!(report.checkpoint_checked, "checkpoint anchors were verified");
    assert_eq!(report.heads.len(), 1);
    assert_eq!(report.heads[0].model, ModelId::default());
    assert_eq!(report.heads[0].chain_len, 3);
    assert_eq!(report.heads[0].head_hash, report.records[2].core_hash());

    // Every link: chained hashes, durable coordinates, embedded evidence.
    let genesis = AuditRecord::genesis_hash(&ModelId::default());
    for (i, rec) in report.records.iter().enumerate() {
        assert_eq!(rec.chain_seq, i as u64 + 1);
        let expect_prev =
            if i == 0 { genesis } else { report.records[i - 1].core_hash() };
        assert_eq!(rec.prev_hash, expect_prev, "link {} prev hash", i + 1);
        assert_eq!(rec.wal_seq, Some(i as u64 + 1));
        assert_eq!(rec.wal_gen, 1);
        assert!(!rec.tainted);
        assert!(!rec.rolled_back);
        let at = rec.attest.as_ref().expect("link records its attestation");
        assert_eq!(at.precision, "f32");
        assert!((0.0..=1.0).contains(&at.forget_acc_before), "link {}", i + 1);
        assert!((0.0..=1.0).contains(&at.retain_acc_before), "link {}", i + 1);
        assert!((0.0..=1.0).contains(&at.mia_before), "link {}", i + 1);
        assert!((0.0..=1.0).contains(&at.mia_after), "link {}", i + 1);
    }

    // `prove` answers for an executed spec and refuses an unexecuted one.
    let links = audit::prove(&dir, None, &ForgetSpec::Class(2)).unwrap();
    assert_eq!(links.len(), 1);
    assert_eq!(links[0].spec, ForgetSpec::Class(2));
    let err = audit::prove(&dir, None, &ForgetSpec::Class(9)).unwrap_err();
    assert!(format!("{err:#}").contains("class:9"), "{err:#}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Tamper evidence: a single flipped byte in `audit.log` (CRC damage)
/// and a CRC-valid forged record (rewritten body) are both rejected,
/// each naming the first record that no longer holds.
#[test]
fn any_single_byte_mutation_is_rejected_naming_the_record() {
    let _g = serial();
    faults::clear();
    let dir = durable_dir("mutate");

    {
        let fleet = durable_fleet(&dir);
        submit_done(&fleet, ForgetSpec::Class(3));
        submit_done(&fleet, ForgetSpec::Classes(vec![1, 4]));
        fleet.shutdown().unwrap();
    }
    audit::verify_dir(&dir).unwrap();
    let path = dir.join(audit::AUDIT_FILE);
    let pristine = std::fs::read(&path).unwrap();

    // Frame layout after the 8-byte magic: `len u32 | crc u32 | body`.
    let len1 = u32::from_le_bytes(pristine[8..12].try_into().unwrap()) as usize;
    let frame2 = 8 + 8 + len1;

    // Flip one byte inside record 2's body: its CRC no longer matches,
    // the scan stops after record 1, and verification refuses the file
    // naming the damaged record.
    let mut bytes = pristine.clone();
    bytes[frame2 + 8 + 10] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", audit::verify_dir(&dir).unwrap_err());
    assert!(err.contains("record 2"), "damaged record is named: {err}");

    // Same flip in record 1's body: now record 1 is named.
    let mut bytes = pristine.clone();
    bytes[8 + 8 + 10] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err = format!("{:#}", audit::verify_dir(&dir).unwrap_err());
    assert!(err.contains("record 1"), "damaged record is named: {err}");

    // Truncated tail (a crash would leave this; a mutation can too):
    // verification refuses rather than silently shortening history.
    std::fs::write(&path, &pristine[..pristine.len() - 3]).unwrap();
    let err = format!("{:#}", audit::verify_dir(&dir).unwrap_err());
    assert!(err.contains("record 2"), "torn record is named: {err}");

    // Forged embedded record, CRC recomputed: rewrite record 1 with an
    // inflated accuracy. The file is frame-valid, but record 2's
    // `prev_hash` no longer matches record 1's core hash.
    let mut records = audit::log::read_log(&path_restore(&path, &pristine)).unwrap().records;
    records[0].forget_acc = 0.999;
    audit::log::write_replacing(&path, &records).unwrap();
    let err = format!("{:#}", audit::verify_dir(&dir).unwrap_err());
    assert!(err.contains("record 2"), "forged link is named: {err}");
    assert!(err.contains("forged or tampered"), "{err}");

    // Forged head: links still chain, but the checkpoint's embedded
    // anchor no longer matches — the divergence is loud.
    let mut records = audit::log::read_log(&path_restore(&path, &pristine)).unwrap().records;
    records[1].retain_acc = 1.0;
    audit::log::write_replacing(&path, &records).unwrap();
    let err = format!("{:#}", audit::verify_dir(&dir).unwrap_err());
    assert!(err.contains("diverged"), "anchor divergence is loud: {err}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Restore `path` to `bytes` and hand the path back — keeps the
/// mutate-verify-restore cadence above readable.
fn path_restore(path: &Path, bytes: &[u8]) -> PathBuf {
    std::fs::write(path, bytes).unwrap();
    path.to_path_buf()
}

/// Kill-and-restart determinism: a run whose last forget is accepted on
/// disk but never served, then recovered, ends with an audit chain
/// whose per-link core hashes are identical to an uninterrupted run's —
/// recovery re-enters the chain, it does not fork it.
#[test]
fn kill_and_restart_recovers_an_identical_chain() {
    let _g = serial();
    faults::clear();
    let dir_a = durable_dir("chain_reference");
    let dir_b = durable_dir("chain_crashed");
    let spec1 = ForgetSpec::Class(3);
    let spec2 = ForgetSpec::Classes(vec![1, 4]);
    let spec3 = ForgetSpec::Class(6);

    // Reference: all three events, no interruption.
    {
        let fleet = durable_fleet(&dir_a);
        for spec in [&spec1, &spec2, &spec3] {
            submit_done(&fleet, spec.clone());
        }
        fleet.shutdown().unwrap();
    }

    // Crashed: two events land; the third is accepted (fsync'd) but the
    // process "dies" before serving it.
    {
        let fleet = durable_fleet(&dir_b);
        submit_done(&fleet, spec1.clone());
        submit_done(&fleet, spec2.clone());
        fleet.shutdown().unwrap();
        let (w, _tail) = wal::Wal::open_append(&dir_b.join(wal::LEDGER_FILE)).unwrap();
        w.append_accepted(&ModelId::default(), &spec3, 0, None).unwrap();
    }

    // Restart: the unserved event replays and appends its link.
    {
        let fleet = durable_fleet(&dir_b);
        assert_eq!(fleet.stats().durability.unwrap().replayed, 1);
        wait_served(&fleet, 1);
        fleet.shutdown().unwrap();
    }

    let a = audit::verify_dir(&dir_a).unwrap();
    let b = audit::verify_dir(&dir_b).unwrap();
    assert_eq!(a.records.len(), 3);
    assert_eq!(b.records.len(), 3);
    // Core hashes cover spec, config, build, accuracies, and the MIA
    // attestation — but not the durability coordinates (the replayed
    // link carries a different `wal_gen`), so identity here means the
    // recovered history *is* the uninterrupted history.
    for (i, (ra, rb)) in a.records.iter().zip(&b.records).enumerate() {
        assert_eq!(
            ra.core_hash(),
            rb.core_hash(),
            "link {}: recovered chain diverged from the uninterrupted run",
            i + 1
        );
    }
    assert_eq!(a.heads, b.heads);

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

/// A failed audit append must not block the caller: the reply is still
/// `Done`, the link enters the in-memory chain flagged `tainted`, later
/// links hash over it, and offline verification then refuses the
/// on-disk log — the hole is permanent evidence, not silence.
#[test]
fn failed_audit_append_taints_without_blocking_replies() {
    let _g = serial();
    faults::clear();
    let dir = durable_dir("taint");

    let fleet = durable_fleet(&dir);
    submit_done(&fleet, ForgetSpec::Class(1));

    // The next audit append dies; the forget itself must still answer.
    faults::arm("audit_append:1:error").unwrap();
    let sm = submit_done(&fleet, ForgetSpec::Class(2));
    faults::clear();
    assert!(!sm.rolled_back);
    assert_eq!(sm.wal_seq, Some(2));

    // A third forget chains over the tainted link.
    submit_done(&fleet, ForgetSpec::Class(4));

    let chain = fleet.audit_chain(&ModelId::default());
    assert_eq!(chain.len(), 3);
    assert!(!chain[0].tainted);
    assert!(chain[1].tainted, "the unpersisted link is flagged, not dropped");
    assert!(!chain[2].tainted);
    assert_eq!(chain[2].prev_hash, chain[1].core_hash(), "later links hash over the hole");

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.merged().served, 3, "serving never paused");

    // On disk the chain jumps 1 -> 3: verification names the hole.
    let err = format!("{:#}", audit::verify_dir(&dir).unwrap_err());
    assert!(err.contains("record 2"), "the missing link is named: {err}");

    let _ = std::fs::remove_dir_all(&dir);
}
