//! End-to-end tests of the HTTP front-end over real loopback sockets:
//! a mock-service fleet behind `HttpServer`, driven by a hand-rolled
//! client. Covers the full wire contract — forget round-trips (200 +
//! summary), 429 with `Retry-After` under backpressure, 504 past a
//! deadline, machine-readable 400s with byte offsets, 404/405/413/500,
//! keep-alive framing, and clean shutdown mid-connection.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::coordinator::{
    Fleet, FleetConfig, HttpConfig, HttpServer, ModelId, Summary, Timing, UnlearnService,
    WorkerSpec,
};
use ficabu::data::{cifar20_like, DatasetCfg};
use ficabu::fisher::Importance;
use ficabu::model::ParamStore;
use ficabu::runtime::Precision;
use ficabu::unlearn::{ForgetSpec, UnlearnConfig};
use ficabu::util::json::Json;

/// Mock worker core (same shape as tests/dispatch.rs): every `unlearn`
/// call announces itself on `started`, then blocks until the test feeds
/// one token through `gate`. `class:13` fails after the gate.
struct MockService {
    wid: usize,
    started: Sender<(usize, ForgetSpec)>,
    gate: Arc<Mutex<Receiver<()>>>,
}

impl UnlearnService for MockService {
    fn unlearn(&mut self, spec: &ForgetSpec) -> anyhow::Result<Summary> {
        let _ = self.started.send((self.wid, spec.clone()));
        self.gate
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| anyhow::anyhow!("gate closed"))?;
        if *spec == ForgetSpec::Class(13) {
            anyhow::bail!("boom on class 13");
        }
        Ok(Summary {
            model: ModelId::default(),
            config_hash: 0,
            spec: spec.clone(),
            forget_acc: 0.04,
            retain_acc: 0.92,
            stop_depth: Some(2),
            macs_vs_ssd_pct: 12.0,
            sim_energy_mj: 1.1,
            sim_energy_vs_ssd_pct: 9.0,
            sim_ms: 0.0,
            rolled_back: false,
            timing: Timing::default(),
            wal_seq: None,
            attest: None,
        })
    }
}

struct Rig {
    started: Receiver<(usize, ForgetSpec)>,
    tokens: Sender<()>,
}

const STARTED_TIMEOUT: Duration = Duration::from_secs(10);

/// A mock fleet behind a bound HTTP server on an ephemeral port.
fn serve(fleet_cfg: FleetConfig, http_cfg: HttpConfig) -> (HttpServer, Arc<Fleet>, Rig) {
    let (started_tx, started_rx) = channel();
    let (token_tx, token_rx) = channel();
    let gate = Arc::new(Mutex::new(token_rx));
    let fleet = Arc::new(
        Fleet::start_with(fleet_cfg, move |wid| {
            Ok(MockService { wid, started: started_tx.clone(), gate: Arc::clone(&gate) })
        })
        .expect("mock fleet starts"),
    );
    let srv = HttpServer::bind("127.0.0.1:0", Arc::clone(&fleet), http_cfg)
        .expect("server binds an ephemeral port");
    (srv, fleet, Rig { started: started_rx, tokens: token_tx })
}

/// Tear down server then fleet, asserting the front-end released every
/// fleet handle.
fn teardown(srv: HttpServer, fleet: Arc<Fleet>) {
    srv.shutdown();
    let fleet = Arc::try_unwrap(fleet)
        .ok()
        .expect("http shutdown releases every fleet handle");
    fleet.shutdown().expect("fleet drains");
}

fn write_request(s: &mut TcpStream, method: &str, path: &str, body: &str) {
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nhost: e2e\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("request written");
}

/// Read one framed response off a keep-alive connection.
fn read_response(r: &mut BufReader<TcpStream>) -> (u16, Vec<(String, String)>, Json) {
    let mut line = String::new();
    r.read_line(&mut line).expect("status line");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|t| t.parse().ok())
        .unwrap_or_else(|| panic!("malformed status line {line:?}"));
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).expect("header line");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h.split_once(':').expect("name: value");
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let len: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse().expect("numeric content-length"))
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).expect("framed body");
    let body = Json::parse(String::from_utf8(body).expect("utf8 body").trim())
        .expect("json body");
    (status, headers, body)
}

/// One-shot request on a fresh connection.
fn roundtrip(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let (status, _, json) = roundtrip_headers(addr, method, path, body);
    (status, json)
}

fn roundtrip_headers(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, Vec<(String, String)>, Json) {
    let mut s = TcpStream::connect(addr).expect("connect");
    write_request(&mut s, method, path, body);
    let mut r = BufReader::new(s);
    read_response(&mut r)
}

#[test]
fn forget_round_trips_with_summary_and_keep_alive() {
    let (srv, fleet, rig) = serve(FleetConfig::default(), HttpConfig::default());
    let addr = srv.local_addr();
    rig.tokens.send(()).unwrap();
    rig.tokens.send(()).unwrap();

    // two requests over ONE connection: keep-alive framing must hold
    let mut s = TcpStream::connect(addr).expect("connect");
    let mut r = BufReader::new(s.try_clone().expect("clone"));
    write_request(&mut s, "POST", "/forget", r#"{"spec": "classes:4,1"}"#);
    let (status, _, j) = read_response(&mut r);
    assert_eq!(status, 200, "body: {j}");
    assert_eq!(j.get("code").unwrap().as_str(), Some("done"));
    let sm = j.get("summary").unwrap();
    // the summary carries the canonical spec, not the submitted order
    assert_eq!(sm.get("spec").unwrap().as_str(), Some("classes:1,4"));
    assert_eq!(sm.get("stop_depth").unwrap().as_i64(), Some(2));
    assert!(sm.get("service_ms").unwrap().as_f64().unwrap() >= 0.0);

    write_request(&mut s, "GET", "/healthz", "");
    let (status, _, j) = read_response(&mut r);
    assert_eq!(status, 200);
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));

    // structured spec form + stats on fresh connections
    let (status, j) = roundtrip(addr, "POST", "/forget", r#"{"spec": {"class": 5}}"#);
    assert_eq!(status, 200, "body: {j}");
    let (status, j) = roundtrip(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    assert_eq!(j.get("rollup").unwrap().get("served").unwrap().as_i64(), Some(2));
    assert!(j.get("rollup").unwrap().get("queue_p99_ms").is_some());

    teardown(srv, fleet);
}

#[test]
fn stalled_fleet_backpressure_is_429_with_retry_after() {
    let cfg = FleetConfig { queue_cap: 1, ..FleetConfig::default() };
    let (srv, fleet, rig) = serve(cfg, HttpConfig::default());
    let addr = srv.local_addr();

    // stall the single worker, then fill the 1-deep queue directly
    let rx0 = fleet.submit(ForgetSpec::Class(0));
    rig.started.recv_timeout(STARTED_TIMEOUT).unwrap();
    let rx1 = fleet.submit(ForgetSpec::Class(1));

    // a distinct wire request must shed immediately with 429
    let (status, headers, j) = roundtrip_headers(addr, "POST", "/forget", r#"{"spec": "class:2"}"#);
    assert_eq!(status, 429, "body: {j}");
    assert!(
        headers.iter().any(|(k, v)| k == "retry-after" && v == "1"),
        "missing retry-after in {headers:?}"
    );
    assert_eq!(j.get("code").unwrap().as_str(), Some("backpressure"));
    assert_eq!(j.get("queue_len").unwrap().as_i64(), Some(1));
    assert_eq!(j.get("queue_cap").unwrap().as_i64(), Some(1));

    rig.tokens.send(()).unwrap();
    rig.tokens.send(()).unwrap();
    rx0.recv().unwrap();
    rx1.recv().unwrap();
    teardown(srv, fleet);
}

#[test]
fn missed_deadline_is_504() {
    let (srv, fleet, rig) = serve(FleetConfig::default(), HttpConfig::default());
    let addr = srv.local_addr();

    // stall the worker so the wire request waits in the queue past its
    // deadline; release the stall only once the wire request is provably
    // admitted (admission starts its 5 ms clock), then 30 ms later
    let rx0 = fleet.submit(ForgetSpec::Class(0));
    rig.started.recv_timeout(STARTED_TIMEOUT).unwrap();
    let tokens = rig.tokens.clone();
    let watch = Arc::clone(&fleet);
    let release = std::thread::spawn(move || {
        let t0 = Instant::now();
        while watch.stats().admitted < 2 && t0.elapsed() < STARTED_TIMEOUT {
            std::thread::sleep(Duration::from_millis(2));
        }
        std::thread::sleep(Duration::from_millis(30));
        tokens.send(()).unwrap();
    });

    let body = r#"{"spec": "class:1", "deadline_ms": 5}"#;
    let (status, j) = roundtrip(addr, "POST", "/forget", body);
    assert_eq!(status, 504, "body: {j}");
    assert_eq!(j.get("code").unwrap().as_str(), Some("expired"));
    assert!(j.get("missed_by_ms").unwrap().as_f64().unwrap() > 0.0);

    release.join().unwrap();
    rx0.recv().unwrap();
    teardown(srv, fleet);
}

#[test]
fn bad_requests_answer_machine_readable_400s() {
    let http_cfg = HttpConfig { bounds: Some((10, 100)), ..HttpConfig::default() };
    let (srv, fleet, _rig) = serve(FleetConfig::default(), http_cfg);
    let addr = srv.local_addr();

    // malformed JSON: offset + context point at the offending byte
    let (status, j) = roundtrip(addr, "POST", "/forget", r#"{"spec": bogus}"#);
    assert_eq!(status, 400);
    assert_eq!(j.get("code").unwrap().as_str(), Some("bad_request"));
    assert_eq!(j.get("offset").unwrap().as_i64(), Some(9));
    assert!(j.get("context").unwrap().as_str().unwrap().contains("bogus"));

    // well-formed but invalid spec: offset points at the spec value
    let (status, j) = roundtrip(addr, "POST", "/forget", r#"{"spec": "nope:1"}"#);
    assert_eq!(status, 400);
    assert_eq!(j.get("code").unwrap().as_str(), Some("invalid_spec"));
    assert_eq!(j.get("offset").unwrap().as_i64(), Some(9));

    // in-grammar but out of the dataset's range: rejected at admission
    let (status, j) = roundtrip(addr, "POST", "/forget", r#"{"spec": "class:42"}"#);
    assert_eq!(status, 400);
    assert_eq!(j.get("code").unwrap().as_str(), Some("invalid_spec"));
    assert!(j.get("error").unwrap().as_str().unwrap().contains("out of range"));

    // missing spec entirely
    let (status, j) = roundtrip(addr, "POST", "/forget", r#"{"deadline_ms": 4}"#);
    assert_eq!(status, 400);
    assert!(j.get("error").unwrap().as_str().unwrap().contains("missing `spec`"));

    teardown(srv, fleet);
}

#[test]
fn unknown_routes_methods_and_oversized_bodies() {
    let http_cfg = HttpConfig { max_body_bytes: 64, ..HttpConfig::default() };
    let (srv, fleet, rig) = serve(FleetConfig::default(), http_cfg);
    let addr = srv.local_addr();

    let (status, j) = roundtrip(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    assert_eq!(j.get("code").unwrap().as_str(), Some("not_found"));

    let (status, headers, j) = roundtrip_headers(addr, "DELETE", "/forget", "");
    assert_eq!(status, 405, "body: {j}");
    assert!(headers.iter().any(|(k, v)| k == "allow" && v == "POST"));

    let big = format!(r#"{{"spec": "class:1", "pad": "{}"}}"#, "x".repeat(128));
    let (status, j) = roundtrip(addr, "POST", "/forget", &big);
    assert_eq!(status, 413);
    assert_eq!(j.get("code").unwrap().as_str(), Some("payload_too_large"));

    // an execution failure maps to 500 with the formatted error
    rig.tokens.send(()).unwrap();
    let (status, j) = roundtrip(addr, "POST", "/forget", r#"{"spec": "class:13"}"#);
    assert_eq!(status, 500);
    assert_eq!(j.get("code").unwrap().as_str(), Some("failed"));
    assert!(j.get("error").unwrap().as_str().unwrap().contains("boom"));

    teardown(srv, fleet);
}

#[test]
fn http10_gets_close_framing() {
    let (srv, fleet, _rig) = serve(FleetConfig::default(), HttpConfig::default());
    let addr = srv.local_addr();

    // a plain 1.0 client relies on EOF framing: the server must answer
    // `connection: close` and actually close, not hold keep-alive
    let mut s = TcpStream::connect(addr).expect("connect");
    write!(s, "GET /healthz HTTP/1.0\r\nhost: e2e\r\n\r\n").expect("request written");
    let mut r = BufReader::new(s);
    let (status, headers, _) = read_response(&mut r);
    assert_eq!(status, 200);
    assert!(
        headers.iter().any(|(k, v)| k == "connection" && v == "close"),
        "HTTP/1.0 default must be close, got {headers:?}"
    );
    let mut rest = Vec::new();
    r.read_to_end(&mut rest).expect("EOF after a 1.0 response");
    assert!(rest.is_empty());

    teardown(srv, fleet);
}

#[test]
fn hostile_payloads_answer_400_and_the_server_survives() {
    let (srv, fleet, rig) = serve(FleetConfig::default(), HttpConfig::default());
    let addr = srv.local_addr();

    // deadline values outside Duration's domain used to panic the accept
    // thread (permanently with the default 2-thread pool); deeply nested
    // bodies used to overflow the scanner's stack and abort the process
    for _ in 0..3 {
        let body = r#"{"spec": "class:1", "deadline_ms": 1e999}"#;
        let (status, j) = roundtrip(addr, "POST", "/forget", body);
        assert_eq!(status, 400, "body: {j}");
        assert!(j.get("error").unwrap().as_str().unwrap().contains("deadline_ms"));
    }
    let nested = format!(r#"{{"spec": {}null}}"#, r#"[{"x":"#.repeat(5_000));
    let (status, j) = roundtrip(addr, "POST", "/forget", &nested);
    assert_eq!(status, 400, "body: {j}");

    // both accept threads are still alive and serving
    rig.tokens.send(()).unwrap();
    let (status, j) = roundtrip(addr, "POST", "/forget", r#"{"spec": "class:1"}"#);
    assert_eq!(status, 200, "body: {j}");

    teardown(srv, fleet);
}

#[test]
fn tenancy_routes_over_the_wire() {
    let (srv, fleet, rig) = serve(FleetConfig::default(), HttpConfig::default());
    let addr = srv.local_addr();
    rig.tokens.send(()).unwrap();

    // the model-addressed route serves the fleet's default model, and
    // the summary carries the tenancy fields the batch key stamped
    let (status, j) = roundtrip(addr, "POST", "/models/default/forget", r#"{"spec": "class:2"}"#);
    assert_eq!(status, 200, "body: {j}");
    let sm = j.get("summary").unwrap();
    assert_eq!(sm.get("model").unwrap().as_str(), Some("default"));
    assert_eq!(sm.get("config_hash").unwrap().as_str(), Some("0000000000000000"));

    // unknown model: machine-readable 404, never admitted
    let (status, j) = roundtrip(addr, "POST", "/models/tenant-z/forget", r#"{"spec": "class:2"}"#);
    assert_eq!(status, 404, "body: {j}");
    assert_eq!(j.get("code").unwrap().as_str(), Some("unknown-model"));
    assert!(j.get("error").unwrap().as_str().unwrap().contains("tenant-z"));

    // the legacy route accepts an optional `model` body field
    rig.tokens.send(()).unwrap();
    let body = r#"{"spec": "class:3", "model": "default"}"#;
    let (status, j) = roundtrip(addr, "POST", "/forget", body);
    assert_eq!(status, 200, "body: {j}");
    let body = r#"{"spec": "class:3", "model": "tenant-z"}"#;
    let (status, j) = roundtrip(addr, "POST", "/forget", body);
    assert_eq!(status, 404, "body: {j}");
    assert_eq!(j.get("code").unwrap().as_str(), Some("unknown-model"));

    // service-factory fleets have no model metadata to list
    let (status, j) = roundtrip(addr, "GET", "/models", "");
    assert_eq!(status, 200);
    assert_eq!(j.get("models").unwrap().as_arr().unwrap().len(), 0);

    teardown(srv, fleet);
}

#[test]
fn models_listing_fields_are_pinned_on_a_production_fleet() {
    // a real single-model fleet synthesizes its own `GET /models` row
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    let dcfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
    let (train, _) = cifar20_like(&dcfg);
    let wspec = WorkerSpec {
        meta: meta.clone(),
        shared: SharedMeta::builtin(),
        params: ParamStore::init(&meta, 3),
        global,
        train,
        cfg: UnlearnConfig::default(),
        precision: Precision::F32,
    };
    let fleet = Arc::new(Fleet::start(wspec, FleetConfig::default()).expect("fleet starts"));
    let srv = HttpServer::bind("127.0.0.1:0", Arc::clone(&fleet), HttpConfig::default())
        .expect("server binds");

    let (status, j) = roundtrip(srv.local_addr(), "GET", "/models", "");
    assert_eq!(status, 200);
    let rows = j.get("models").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    let row = &rows[0];
    // wire pin: every field a client switches on, with their formats
    assert_eq!(row.get("id").unwrap().as_str(), Some("default"));
    assert_eq!(row.get("spec_key").unwrap().as_str().unwrap().len(), 16);
    assert_eq!(row.get("config_hash").unwrap().as_str().unwrap().len(), 16);
    assert_eq!(row.get("precision").unwrap().as_str(), Some("f32"));
    assert_eq!(row.get("warm").unwrap().as_bool(), Some(true));

    teardown(srv, fleet);
}

#[test]
fn shutdown_mid_connection_unblocks_the_client() {
    let (srv, fleet, rig) = serve(FleetConfig::default(), HttpConfig::default());
    let addr = srv.local_addr();
    rig.tokens.send(()).unwrap();

    // a live keep-alive connection, idle after one served request: the
    // server side is blocked reading the next request head
    let mut s = TcpStream::connect(addr).expect("connect");
    let mut r = BufReader::new(s.try_clone().expect("clone"));
    write_request(&mut s, "POST", "/forget", r#"{"spec": "class:3"}"#);
    let (status, _, _) = read_response(&mut r);
    assert_eq!(status, 200);

    // shutdown must not wait for the idle peer: it force-closes the
    // registered connection and joins the accept pool promptly
    let t0 = Instant::now();
    srv.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown hung on an idle keep-alive connection"
    );

    // the client sees the close as EOF (or a reset), never a hang
    let mut rest = Vec::new();
    let _ = r.read_to_end(&mut rest);
    assert!(rest.is_empty(), "no bytes after shutdown, got {}", rest.len());

    let fleet = Arc::try_unwrap(fleet)
        .ok()
        .expect("http shutdown releases every fleet handle");
    fleet.shutdown().expect("fleet drains");
}
