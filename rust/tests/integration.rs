//! Integration tests across runtime + model + unlearn + metrics + hwsim,
//! running end-to-end on the default CpuBackend (no artifacts, no XLA).
//!
//! These use freshly initialized (untrained) parameters where possible to
//! stay fast; the trained-model behaviour is exercised by the examples and
//! the table benches. Every source of randomness is an explicitly seeded
//! `Pcg32`, so the suite is bit-deterministic across runs and machines.

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::data::{cifar20_like, DatasetCfg};
use ficabu::fisher::{FimdEngine, Importance};
use ficabu::hwsim::mem::Precision;
use ficabu::hwsim::{BaselineProcessor, FicabuProcessor};
use ficabu::metrics::{eval_accuracy, per_sample_losses};
use ficabu::model::macs::ssd_ledger;
use ficabu::model::{Model, ParamStore};
use ficabu::runtime::Runtime;
use ficabu::unlearn::{
    default_checkpoints, make_onehot, run_strategy, Bd, Cau, Schedule, Ssd,
};
use ficabu::util::prng::Pcg32;

struct Ctx {
    model: Model,
    params: ParamStore,
    fimd: FimdEngine,
    damp: ficabu::unlearn::DampEngine,
    _rt: Runtime,
}

fn ctx(model_name: &str) -> Ctx {
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::builtin(model_name).unwrap();
    let shared = SharedMeta::builtin();
    let model = Model::load(&rt, meta.clone()).unwrap();
    let params = ParamStore::init(&meta, 42);
    let fimd = FimdEngine::new(&rt, &shared).unwrap();
    let damp = ficabu::unlearn::DampEngine::new(&rt, &shared).unwrap();
    Ctx { model, params, fimd, damp, _rt: rt }
}

fn forget_batch(meta: &ModelMeta, class: usize, seed: u64) -> (ficabu::tensor::Tensor, Vec<usize>) {
    let cfg = DatasetCfg { train_per_class: 8, test_per_class: 1, ..DatasetCfg::cifar20() };
    let (train, _) = cifar20_like(&cfg);
    let mut rng = Pcg32::seeded(seed);
    train.forget_batch(class, meta.batch, &mut rng)
}

#[test]
fn ssd_mode_ledger_matches_analytic_ssd_ledger() {
    let mut c = ctx("rn18slim");
    let meta = c.model.meta.clone();
    let (x, labels) = forget_batch(&meta, 0, 1);
    let global = {
        let mut g = Importance::zeros_like(&meta);
        g.floor(1.0); // uniform global importance
        g
    };
    let report = run_strategy(
        &c.model, &mut c.params, &x, &labels, &global, &c.fimd, &c.damp, &Ssd::new(10.0, 1.0),
    )
    .unwrap();
    // SSD (no checkpoints) must edit every segment and cost exactly the
    // analytic SSD ledger
    assert_eq!(report.segments_edited, meta.num_segments());
    assert!(report.stop_depth.is_none());
    let analytic = ssd_ledger(&meta, meta.batch);
    assert_eq!(report.ledger.total(), analytic.total());
    assert_eq!(report.ledger.checkpoint, 0);
}

#[test]
fn early_stop_leaves_front_end_untouched() {
    let mut c = ctx("rn18slim");
    let meta = c.model.meta.clone();
    let before = c.params.clone();
    let (x, labels) = forget_batch(&meta, 2, 3);
    // tau = 1.0 -> first checkpoint always satisfies the target
    let global = {
        let mut g = Importance::zeros_like(&meta);
        g.floor(1e-6);
        g
    };
    let report = run_strategy(
        &c.model, &mut c.params, &x, &labels, &global, &c.fimd, &c.damp,
        &Cau::new(10.0, 1.0, vec![1], 1.0),
    )
    .unwrap();
    assert_eq!(report.stop_depth, Some(1));
    // all segments except the head must be bit-identical
    for k in 0..meta.num_segments() - 1 {
        for (a, b) in before.seg[k].iter().zip(&c.params.seg[k]) {
            assert_eq!(a.data, b.data, "segment {k} was modified");
        }
    }
    // checkpoint overhead accounted
    assert!(report.ledger.checkpoint > 0);
}

#[test]
fn balanced_dampening_weakens_front_end_edits() {
    // with S(l) scaling, the front-end (large l) sees larger alpha (fewer
    // selections): compare uniform vs sigmoid selection counts per depth
    let run = |schedule: Schedule| {
        let mut c = ctx("rn18slim");
        let meta = c.model.meta.clone();
        let (x, labels) = forget_batch(&meta, 1, 7);
        let mut global = Importance::zeros_like(&meta);
        global.floor(1e-6);
        run_strategy(
            &c.model, &mut c.params, &x, &labels, &global, &c.fimd, &c.damp,
            &Bd::new(1.0, 1.0, schedule),
        )
        .unwrap()
        .selected_per_depth
    };
    let uni = run(Schedule::Uniform);
    let sig = run(Schedule::Sigmoid { cm: 5.0, br: 10.0 });
    let big_l = uni.len();
    // back-end (l=1): S=1 -> identical selection
    assert_eq!(uni[0], sig[0]);
    // front-end: strictly fewer (or equal) selections under the sigmoid
    assert!(sig[big_l - 1] <= uni[big_l - 1]);
    let uni_front: u64 = uni[big_l / 2..].iter().sum();
    let sig_front: u64 = sig[big_l / 2..].iter().sum();
    assert!(
        sig_front < uni_front,
        "sigmoid front-end selections {sig_front} !< uniform {uni_front}"
    );
}

#[test]
fn unlearning_is_deterministic() {
    let run = || {
        let mut c = ctx("rn18slim");
        let meta = c.model.meta.clone();
        let (x, labels) = forget_batch(&meta, 4, 11);
        let mut global = Importance::zeros_like(&meta);
        global.floor(1e-6);
        run_strategy(
            &c.model, &mut c.params, &x, &labels, &global, &c.fimd, &c.damp,
            &Ssd::new(5.0, 1.0),
        )
        .unwrap();
        c.params.seg[9][0].data.clone()
    };
    assert_eq!(run(), run());
}

#[test]
fn dampening_never_increases_magnitude() {
    let mut c = ctx("vitslim");
    let meta = c.model.meta.clone();
    let before = c.params.clone();
    let (x, labels) = forget_batch(&meta, 0, 13);
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    run_strategy(
        &c.model, &mut c.params, &x, &labels, &global, &c.fimd, &c.damp, &Ssd::new(1.0, 0.5),
    )
    .unwrap();
    for (sb, sa) in before.seg.iter().zip(&c.params.seg) {
        for (tb, ta) in sb.iter().zip(sa) {
            for (vb, va) in tb.data.iter().zip(&ta.data) {
                assert!(va.abs() <= vb.abs() + 1e-6);
            }
        }
    }
}

#[test]
fn metrics_pipeline_on_untrained_model_is_chance_level() {
    let c = ctx("rn18slim");
    let cfg = DatasetCfg { train_per_class: 4, test_per_class: 2, ..DatasetCfg::cifar20() };
    let (train, _) = cifar20_like(&cfg);
    let idx: Vec<usize> = (0..train.len()).collect();
    let acc = eval_accuracy(&c.model, &c.params, &train, &idx).unwrap();
    assert!(acc < 0.3, "untrained model should be near chance, got {acc}");
    let losses = per_sample_losses(&c.model, &c.params, &train, &idx).unwrap();
    assert_eq!(losses.len(), idx.len());
    assert!(losses.iter().all(|&l| l.is_finite() && l > 0.0));
}

#[test]
fn hwsim_costs_track_ledger_scale() {
    let mut c = ctx("rn18slim");
    let meta = c.model.meta.clone();
    let (x, labels) = forget_batch(&meta, 0, 17);
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    // full SSD run vs head-only run
    let full = run_strategy(
        &c.model, &mut c.params, &x, &labels, &global, &c.fimd, &c.damp, &Ssd::new(10.0, 1.0),
    )
    .unwrap();
    let mut c2 = ctx("rn18slim");
    let head_only = run_strategy(
        &c2.model, &mut c2.params, &x, &labels, &global, &c2.fimd, &c2.damp,
        &Cau::new(10.0, 1.0, vec![1], 1.0),
    )
    .unwrap();
    let fic = FicabuProcessor::new(meta.tile, Precision::Int8);
    let base = BaselineProcessor::new(meta.tile, Precision::Int8);
    let e_full_base = base.cost(&full).energy_mj;
    let e_head_fic = fic.cost(&head_only).energy_mj;
    assert!(
        e_head_fic < e_full_base * 0.5,
        "early-stop on FiCABU hw must cost far less: {e_head_fic} vs {e_full_base}"
    );
}

#[test]
fn train_step_then_unlearn_composes() {
    // minimal composition: a few training steps, then a head-only
    // unlearning event, all through compiled modules
    let mut c = ctx("rn18slim");
    let meta = c.model.meta.clone();
    let cfg = DatasetCfg { train_per_class: 8, test_per_class: 1, ..DatasetCfg::cifar20() };
    let (train, _) = cifar20_like(&cfg);
    let mut rng = Pcg32::seeded(19);
    for _ in 0..3 {
        let idx = rng.choose_k(train.len(), meta.batch);
        let (x, labels) = train.batch(&idx, meta.batch);
        let onehot = make_onehot(&labels, meta.num_classes).unwrap();
        let loss = c.model.train_step(&mut c.params, &x, &onehot, 0.05).unwrap();
        assert!(loss.is_finite());
    }
    let (x, labels) = train.forget_batch(0, meta.batch, &mut rng);
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    let cps = default_checkpoints(meta.num_segments(), 2);
    let report = run_strategy(
        &c.model, &mut c.params, &x, &labels, &global, &c.fimd, &c.damp,
        &Cau::new(10.0, 1.0, cps, 0.05),
    )
    .unwrap();
    assert!(report.segments_edited >= 1);
}
