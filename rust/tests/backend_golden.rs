//! Golden-value and gradient tests for the `Backend` trait.
//!
//! Part 1 pins the CpuBackend engine modules (GEMM / FIMD / dampening)
//! to fixtures derived from the pure-jnp oracles in
//! `python/compile/kernels/ref.py`:
//!   ref_matmul(x, y)              = x @ y
//!   ref_fimd_update(g, a, s)      = a + s[0] * g * g
//!   ref_dampen(th, idf, id, a, l) = where(idf > a*id,
//!                                         min(l*id/max(idf,1e-30),1)*th, th)
//!
//! Part 2 cross-checks every hand-written segment VJP against central
//! finite differences of the segment forward — the property `jax.vjp`
//! guaranteed on the XLA path.

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::model::ParamStore;
use ficabu::runtime::cpu::kernels::Conv;
use ficabu::runtime::{Executable, ModuleSpec, Runtime};
use ficabu::tensor::Tensor;
use ficabu::util::prng::Pcg32;

fn shared() -> SharedMeta {
    SharedMeta::builtin()
}

// ---------------------------------------------------------------------------
// Part 1: engine-module fixtures (ref.py oracles)
// ---------------------------------------------------------------------------

#[test]
fn gemm_module_matches_ref_matmul_fixture() {
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&ModuleSpec::Gemm { shared: shared() }).unwrap();
    // ref_matmul([[1,2,3],[4,5,6]], [[7,8],[9,10],[11,12]])
    //   = [[58,64],[139,154]]
    let x = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
    let y = Tensor::new(vec![3, 2], vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]).unwrap();
    let out = exe.run(&[&x, &y]).unwrap();
    assert_eq!(out[0].shape, vec![2, 2]);
    assert_eq!(out[0].data, vec![58.0, 64.0, 139.0, 154.0]);
}

#[test]
fn gemm_module_matches_f64_reference() {
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&ModuleSpec::Gemm { shared: shared() }).unwrap();
    let (m, k, n) = (17, 23, 13);
    let mut rng = Pcg32::seeded(0x6e44);
    let x = Tensor::new(vec![m, k], rng.normal_vec(m * k, 1.0)).unwrap();
    let y = Tensor::new(vec![k, n], rng.normal_vec(k * n, 1.0)).unwrap();
    let out = exe.run(&[&x, &y]).unwrap();
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += x.data[i * k + p] as f64 * y.data[p * n + j] as f64;
            }
            let got = out[0].data[i * n + j] as f64;
            assert!((got - acc).abs() < 1e-4, "[{i},{j}]: {got} vs {acc}");
        }
    }
}

#[test]
fn fimd_module_matches_ref_fixture() {
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&ModuleSpec::Fimd { shared: shared() }).unwrap();
    let t = shared().tile;
    // ref_fimd_update(g, a, s) = a + s*g^2 with g = i mod 5, a = 0.5, s = 0.2:
    // lanes cycle through [0.5, 0.7, 1.3, 2.3, 3.7]
    let grad = Tensor::vec1((0..t).map(|i| (i % 5) as f32).collect());
    let acc = Tensor::vec1(vec![0.5; t]);
    let scale = Tensor::vec1(vec![0.2]);
    let out = exe.run(&[&grad, &acc, &scale]).unwrap();
    let golden = [0.5f32, 0.7, 1.3, 2.3, 3.7];
    for i in 0..t {
        let want = golden[i % 5];
        assert!((out[0].data[i] - want).abs() < 1e-6, "lane {i}");
    }
}

#[test]
fn dampen_module_matches_ref_fixture() {
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&ModuleSpec::Dampen { shared: shared() }).unwrap();
    let t = shared().tile;
    // Five-lane fixture from ref_dampen with alpha = 2, lambda = 1:
    //   lane 0: idf=8,  id=1 -> sel, beta=min(1/8,1)=0.125 -> 0.375
    //   lane 1: idf=2,  id=1 -> 2 > 2 false -> untouched      3.0
    //   lane 2: idf=0,  id=1 -> unselected                    3.0
    //   lane 3: idf=1,  id=0.25 -> 1 > 0.5 sel, beta=min(.25,1)=0.25 -> 0.75
    //   lane 4: idf=3,  id=1 -> sel, beta=min(1/3,1) -> 1.0 (3*1/3)
    let idf_v = [8.0f32, 2.0, 0.0, 1.0, 3.0];
    let idd_v = [1.0f32, 1.0, 1.0, 0.25, 1.0];
    let want_t = [0.375f32, 3.0, 3.0, 0.75, 1.0];
    let want_m = [1.0f32, 0.0, 0.0, 1.0, 1.0];
    let theta = Tensor::vec1(vec![3.0; t]);
    let idf = Tensor::vec1((0..t).map(|i| idf_v[i % 5]).collect());
    let idd = Tensor::vec1((0..t).map(|i| idd_v[i % 5]).collect());
    let alpha = Tensor::vec1(vec![2.0]);
    let lam = Tensor::vec1(vec![1.0]);
    let out = exe.run(&[&theta, &idf, &idd, &alpha, &lam]).unwrap();
    for i in 0..t {
        assert!(
            (out[0].data[i] - want_t[i % 5]).abs() < 1e-6,
            "theta lane {i}: {} vs {}",
            out[0].data[i],
            want_t[i % 5]
        );
        assert_eq!(out[1].data[i], want_m[i % 5], "mask lane {i}");
    }
}

#[test]
fn conv_kernel_matches_direct_convolution() {
    // im2col+GEMM lowering vs a naive direct conv (ref_conv2d semantics:
    // NHWC/HWIO, SAME padding kh/2, square stride)
    for stride in [1usize, 2] {
        let cv = Conv { kh: 3, kw: 3, cin: 2, cout: 3, stride };
        let (b, h, w) = (2usize, 8usize, 8usize);
        let mut rng = Pcg32::seeded(7 + stride as u64);
        let x = rng.normal_vec(b * h * w * cv.cin, 1.0);
        let wk = rng.normal_vec(cv.kh * cv.kw * cv.cin * cv.cout, 0.5);
        let y = cv.fwd(&x, &wk, b, h, w);
        let (ho, wo) = cv.out_hw(h, w);
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    for co in 0..cv.cout {
                        let mut acc = 0.0f32;
                        for ky in 0..3 {
                            let iy = (oy * stride + ky) as isize - 1;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..3 {
                                let ix = (ox * stride + kx) as isize - 1;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                for ci in 0..cv.cin {
                                    let xv = x[((bi * h + iy as usize) * w
                                        + ix as usize)
                                        * cv.cin
                                        + ci];
                                    let wv = wk[((ky * 3 + kx) * cv.cin + ci) * cv.cout
                                        + co];
                                    acc += xv * wv;
                                }
                            }
                        }
                        let got = y[((bi * ho + oy) * wo + ox) * cv.cout + co];
                        assert!(
                            (got - acc).abs() < 1e-4,
                            "stride {stride} at ({bi},{oy},{ox},{co}): {got} vs {acc}"
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Part 2: segment VJPs vs central finite differences
// ---------------------------------------------------------------------------

/// Probe a few spread coordinates of a buffer.
fn probes(len: usize) -> Vec<usize> {
    let mut v = vec![0, len / 3, len / 2, len - 1];
    v.dedup();
    v
}

fn assert_grad_close(ana: f32, fd: f64, what: &str) {
    let ana = ana as f64;
    let tol = 0.05 + 0.05 * ana.abs().max(fd.abs());
    assert!(
        (ana - fd).abs() <= tol,
        "{what}: analytic {ana} vs finite-diff {fd} (tol {tol})"
    );
}

/// Check d/dx and d/dparams of J = <segment_fwd(params, x), g> against
/// central differences through the forward module.
fn check_segment_gradients(model_name: &str, seg_k: usize, seed: u64) {
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::builtin(model_name).unwrap();
    let fwd = rt
        .load(&ModuleSpec::SegmentFwd { meta: meta.clone(), seg: seg_k })
        .unwrap();
    let bwd = rt
        .load(&ModuleSpec::SegmentBwd { meta: meta.clone(), seg: seg_k })
        .unwrap();
    let seg = &meta.segments[seg_k];
    let mut rng = Pcg32::seeded(seed);
    let b = 2usize;

    let params: Vec<Tensor> = ParamStore::init(&meta, seed ^ 0x9e37).seg[seg_k].clone();
    let n_in: usize = seg.in_shape.iter().product();
    let mut xshape = vec![b];
    xshape.extend_from_slice(&seg.in_shape);
    let x = Tensor::new(xshape, rng.normal_vec(b * n_in, 0.5)).unwrap();
    let n_out: usize = seg.out_shape.iter().product();
    let mut gshape = vec![b];
    gshape.extend_from_slice(&seg.out_shape);
    let g = Tensor::new(gshape, rng.normal_vec(b * n_out, 1.0)).unwrap();

    // analytic gradients through the bwd module
    let mut args: Vec<&Tensor> = params.iter().collect();
    args.push(&x);
    args.push(&g);
    let mut outs = bwd.run(&args).unwrap();
    let gx = outs.pop().unwrap();
    let grads = outs;
    assert_eq!(grads.len(), seg.params.len(), "{}: grad count", seg.name);

    // J(params, x) accumulated in f64 to keep FD noise below tolerance
    let j = |ps: &[Tensor], xt: &Tensor| -> f64 {
        let mut a: Vec<&Tensor> = ps.iter().collect();
        a.push(xt);
        let y = fwd.run(&a).unwrap().pop().unwrap();
        y.data.iter().zip(&g.data).map(|(&u, &v)| u as f64 * v as f64).sum()
    };
    let eps = 5e-3f32;

    for &i in &probes(x.len()) {
        let mut xp = x.clone();
        xp.data[i] += eps;
        let mut xm = x.clone();
        xm.data[i] -= eps;
        let fd = (j(&params, &xp) - j(&params, &xm)) / (2.0 * eps as f64);
        assert_grad_close(gx.data[i], fd, &format!("{}.dx[{i}]", seg.name));
    }
    for (ti, grad) in grads.iter().enumerate() {
        assert_eq!(grad.shape, seg.params[ti].shape, "{}: grad shape {ti}", seg.name);
        for &i in &probes(grad.len()) {
            let mut pp = params.clone();
            pp[ti].data[i] += eps;
            let mut pm = params.clone();
            pm[ti].data[i] -= eps;
            let fd = (j(&pp, &x) - j(&pm, &x)) / (2.0 * eps as f64);
            assert_grad_close(
                grad.data[i],
                fd,
                &format!("{}.d{}[{i}]", seg.name, seg.params[ti].name),
            );
        }
    }
}

#[test]
fn stem_vjp_matches_finite_differences() {
    check_segment_gradients("rn18slim", 0, 101);
}

#[test]
fn identity_block_vjp_matches_finite_differences() {
    check_segment_gradients("rn18slim", 1, 102); // s1b1: stride 1, no shortcut conv
}

#[test]
fn downsample_block_vjp_matches_finite_differences() {
    check_segment_gradients("rn18slim", 3, 103); // s2b1: stride 2 + 1x1 shortcut
}

#[test]
fn gap_head_vjp_matches_finite_differences() {
    check_segment_gradients("rn18slim", 9, 104);
}

#[test]
fn embed_vjp_matches_finite_differences() {
    check_segment_gradients("vitslim", 0, 105);
}

#[test]
fn encoder_vjp_matches_finite_differences() {
    check_segment_gradients("vitslim", 1, 106);
}

#[test]
fn vit_head_vjp_matches_finite_differences() {
    check_segment_gradients("vitslim", 13, 107);
}

// ---------------------------------------------------------------------------
// loss_grad module against its defining formula
// ---------------------------------------------------------------------------

#[test]
fn loss_grad_matches_softmax_formula() {
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let exe = rt.load(&ModuleSpec::LossGrad { meta: meta.clone() }).unwrap();
    let (b, c) = (4usize, meta.num_classes);
    let mut rng = Pcg32::seeded(0x10556);
    let logits = Tensor::new(vec![b, c], rng.normal_vec(b * c, 2.0)).unwrap();
    let mut onehot = Tensor::zeros(vec![b, c]);
    for i in 0..b {
        onehot.data[i * c + (i * 3) % c] = 1.0;
    }
    let out = exe.run(&[&logits, &onehot]).unwrap();
    let probs = logits.softmax_rows();
    for i in 0..b * c {
        let want = (probs.data[i] - onehot.data[i]) / b as f32;
        assert!((out[0].data[i] - want).abs() < 1e-6);
    }
    // rows sum to zero (softmax minus a distribution)
    for i in 0..b {
        let s: f32 = out[0].row(i).iter().sum();
        assert!(s.abs() < 1e-5);
    }
}

/// The FD harness drives `Executable::run` directly; make sure the stats
/// counters on the shared handle advance (Backend-trait plumbing).
#[test]
fn executable_stats_advance() {
    let rt = Runtime::cpu().unwrap();
    let exe: std::rc::Rc<Executable> =
        rt.load(&ModuleSpec::Gemm { shared: shared() }).unwrap();
    let x = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
    exe.run(&[&x, &x]).unwrap();
    exe.run(&[&x, &x]).unwrap();
    assert_eq!(exe.stats().runs, 2);
    assert_eq!(rt.stats().runs, 2);
    assert_eq!(rt.stats().compiles, 1);
}
