//! `FICABU_THREADS` determinism check, isolated in its own test binary:
//! `std::env::set_var` is process-global, and keeping this the only test
//! in the process means no sibling test reads the environment (every
//! GEMM call consults `FICABU_THREADS`) while it is being mutated.

use ficabu::runtime::cpu::gemm;
use ficabu::runtime::cpu::scratch::Scratch;
use ficabu::util::prng::Pcg32;

#[test]
fn ficabu_threads_env_does_not_change_results() {
    let (m, k, n) = (130, 700, 90); // big enough to clear the fork threshold
    let mut rng = Pcg32::seeded(0xdead);
    let a = rng.normal_vec(m * k, 1.0);
    let b = rng.normal_vec(k * n, 1.0);
    let mut sc = Scratch::new();

    std::env::set_var("FICABU_THREADS", "1");
    assert_eq!(gemm::effective_threads(), 1);
    let mut y1 = vec![0.0f32; m * n];
    gemm::matmul_into(&mut sc, &a, &b, m, k, n, &mut y1);

    std::env::set_var("FICABU_THREADS", "4");
    assert_eq!(gemm::effective_threads(), 4);
    let mut y4 = vec![0.0f32; m * n];
    gemm::matmul_into(&mut sc, &a, &b, m, k, n, &mut y4);

    std::env::remove_var("FICABU_THREADS");
    for (i, (u, v)) in y1.iter().zip(&y4).enumerate() {
        assert_eq!(
            u.to_bits(),
            v.to_bits(),
            "FICABU_THREADS=1 vs 4 diverges at [{i}]: {u} vs {v}"
        );
    }
}
