//! Property tests for the tiled multi-threaded GEMM core and the fused
//! conv lowering against the retained PR-1 naive oracles
//! (`kernels::naive`), over randomized shapes that exercise every edge:
//! M/N/K not divisible by the micro-tile sizes, k=1, single-row/column
//! operands, 1x1 convs, strided convs, and padding boundaries. Plus a
//! `FICABU_THREADS` determinism check: worker count must never change a
//! single bit of the output.

use ficabu::runtime::cpu::gemm;
use ficabu::runtime::cpu::kernels::{naive, Conv};
use ficabu::runtime::cpu::scratch::Scratch;
use ficabu::util::prng::Pcg32;

/// Relative 1e-4 tolerance at the accumulation scale: tiled and naive
/// kernels sum the same k products in different orders, so the error
/// budget grows with sqrt(k) for unit-variance operands.
fn assert_close(got: &[f32], want: &[f32], k: usize, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    let scale = 1.0 + (k as f32).sqrt();
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = 1e-4 * (scale + w.abs());
        assert!(
            (g - w).abs() <= tol,
            "{what}[{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 1),
    (3, 1, 5),
    (4, 8, 8),
    (5, 9, 7),
    (8, 64, 8),
    (13, 17, 11),
    (64, 64, 64),
    (33, 129, 65),
    (100, 37, 129),
    (257, 96, 35),
];

#[test]
fn tiled_matmul_matches_naive_over_shapes() {
    let mut rng = Pcg32::seeded(0x71ed);
    let mut sc = Scratch::new();
    for &(m, k, n) in SHAPES {
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let want = naive::matmul(&a, &b, m, k, n);
        let mut got = vec![0.0f32; m * n];
        gemm::matmul_into(&mut sc, &a, &b, m, k, n, &mut got);
        assert_close(&got, &want, k, &format!("matmul {m}x{k}x{n}"));
    }
}

#[test]
fn tiled_matmul_tn_matches_naive_over_shapes() {
    let mut rng = Pcg32::seeded(0x71ee);
    let mut sc = Scratch::new();
    for &(r, m, n) in SHAPES {
        let a = rng.normal_vec(r * m, 1.0); // [r,m], logical A = aᵀ
        let b = rng.normal_vec(r * n, 1.0);
        let want = naive::matmul_tn(&a, &b, r, m, n);
        let mut got = vec![0.0f32; m * n];
        gemm::matmul_tn_into(&mut sc, &a, &b, r, m, n, &mut got);
        assert_close(&got, &want, r, &format!("matmul_tn {r}x{m}x{n}"));
    }
}

#[test]
fn tiled_matmul_nt_matches_naive_over_shapes() {
    let mut rng = Pcg32::seeded(0x71ef);
    let mut sc = Scratch::new();
    for &(m, k, n) in SHAPES {
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(n * k, 1.0); // [n,k], logical B = bᵀ
        let want = naive::matmul_nt(&a, &b, m, k, n);
        let mut got = vec![0.0f32; m * n];
        gemm::matmul_nt_into(&mut sc, &a, &b, m, k, n, &mut got);
        assert_close(&got, &want, k, &format!("matmul_nt {m}x{k}x{n}"));
    }
}

#[test]
fn fused_conv_fwd_matches_naive() {
    // (kh, kw, cin, cout, stride, b, h, w) — 1x1 kernels, strides,
    // non-square and non-divisible spatial dims, multi-batch
    let cases = [
        (1, 1, 1, 1, 1, 1, 2, 2),
        (1, 1, 3, 8, 1, 2, 5, 5),
        (1, 1, 4, 4, 2, 1, 8, 8),
        (3, 3, 1, 1, 1, 1, 3, 3),
        (3, 3, 2, 3, 1, 2, 7, 5),
        (3, 3, 3, 8, 2, 1, 9, 9),
        (5, 5, 2, 2, 1, 1, 6, 6),
    ];
    let mut rng = Pcg32::seeded(0xc0de);
    let mut sc = Scratch::new();
    for &(kh, kw, cin, cout, stride, b, h, w) in &cases {
        let cv = Conv { kh, kw, cin, cout, stride };
        let x = rng.normal_vec(b * h * w * cin, 1.0);
        let wk = rng.normal_vec(kh * kw * cin * cout, 0.5);
        let want = naive::conv_fwd(&cv, &x, &wk, b, h, w);
        let (ho, wo) = cv.out_hw(h, w);
        let mut got = vec![0.0f32; b * ho * wo * cout];
        cv.fwd_into(&mut sc, &x, &wk, b, h, w, &mut got);
        let kk = kh * kw * cin;
        assert_close(&got, &want, kk, &format!("conv {kh}x{kw} s{stride} {cin}->{cout}"));
    }
}

#[test]
fn fused_conv_bwd_matches_naive() {
    let cases = [
        (1, 1, 2, 3, 1, 1, 4, 4),
        (1, 1, 4, 4, 2, 1, 8, 8),
        (3, 3, 2, 3, 1, 2, 7, 5),
        (3, 3, 3, 4, 2, 1, 9, 9),
    ];
    let mut rng = Pcg32::seeded(0xbeef);
    let mut sc = Scratch::new();
    for &(kh, kw, cin, cout, stride, b, h, w) in &cases {
        let cv = Conv { kh, kw, cin, cout, stride };
        let (ho, wo) = cv.out_hw(h, w);
        let x = rng.normal_vec(b * h * w * cin, 1.0);
        let wk = rng.normal_vec(kh * kw * cin * cout, 0.5);
        let gy = rng.normal_vec(b * ho * wo * cout, 1.0);
        let (want_dx, want_dw) = naive::conv_bwd(&cv, &x, &wk, &gy, b, h, w);
        let mut dx = vec![0.0f32; b * h * w * cin];
        let mut dw = vec![0.0f32; kh * kw * cin * cout];
        cv.bwd_into(&mut sc, &x, &wk, &gy, b, h, w, &mut dx, &mut dw);
        let what = format!("conv-bwd {kh}x{kw} s{stride} {cin}->{cout}");
        // dW accumulates over b*ho*wo patch rows; dx over cout
        assert_close(&dw, &want_dw, b * ho * wo, &format!("{what}: dw"));
        assert_close(&dx, &want_dx, cout * kh * kw, &format!("{what}: dx"));
    }
}

#[test]
fn thread_count_does_not_change_results() {
    // Threads only partition output rows; every element accumulates in
    // the same order, so results must be bitwise identical. The
    // FICABU_THREADS env path is exercised in its own test binary
    // (`tests/gemm_threads_env.rs`) so no parallel test reads the
    // environment while it is being mutated.
    let (m, k, n) = (130, 700, 90); // big enough to clear the fork threshold
    let mut rng = Pcg32::seeded(0xdead);
    let a = rng.normal_vec(m * k, 1.0);
    let b = rng.normal_vec(k * n, 1.0);
    let mut sc = Scratch::new();
    let av = gemm::Strided { data: &a, rs: k, cs: 1 };
    let bv = gemm::Strided { data: &b, rs: n, cs: 1 };
    let mut y1 = vec![0.0f32; m * n];
    gemm::gemm_threads(&mut sc, &av, &bv, m, k, n, &mut y1, 1);
    for threads in [2usize, 3, 4, 7] {
        let mut yt = vec![0.0f32; m * n];
        gemm::gemm_threads(&mut sc, &av, &bv, m, k, n, &mut yt, threads);
        for (i, (u, v)) in y1.iter().zip(&yt).enumerate() {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "threads={threads} diverges at [{i}]: {u} vs {v}"
            );
        }
    }
}

#[test]
fn scratch_stops_allocating_at_steady_state() {
    // repeated same-shape GEMMs must hit the arena, not the allocator
    let (m, k, n) = (64, 576, 64);
    let mut rng = Pcg32::seeded(0x5c7a);
    let a = rng.normal_vec(m * k, 1.0);
    let b = rng.normal_vec(k * n, 1.0);
    let mut sc = Scratch::new();
    let mut out = vec![0.0f32; m * n];
    gemm::matmul_into(&mut sc, &a, &b, m, k, n, &mut out);
    let grows_after_first = sc.grows();
    for _ in 0..10 {
        gemm::matmul_into(&mut sc, &a, &b, m, k, n, &mut out);
    }
    assert_eq!(
        sc.grows(),
        grows_after_first,
        "steady-state GEMMs must reuse pooled panels"
    );
    assert_eq!(sc.takes(), 11);
}
