//! Trained-model attestation acceptance: unlearning a class the model
//! actually fitted must drive the attested MIA member-rate *down*, and
//! the evidence must land in the model's audit chain link.
//!
//! The untrained fixtures in `tests/audit_e2e.rs` exercise the chain
//! mechanics cheaply but cannot pin the member-rate's direction — a
//! random-init network has no members. This test trains first, so the
//! forget set is genuinely member-like (low loss) before the edit.
//!
//! In its own binary because it mutates `FICABU_ARTIFACTS` — tests that
//! touch the process environment get a dedicated process (same rule as
//! `tests/int8_e2e.rs`). Trains for 120 steps like the quickstart
//! example, so it is among the slowest tests in the suite.

use ficabu::audit;
use ficabu::config::SharedMeta;
use ficabu::coordinator::{
    DurabilityConfig, Fleet, FleetConfig, Pacing, Reply, WorkerSpec,
};
use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::unlearn::ForgetSpec;

#[test]
fn attested_member_rate_drops_on_a_trained_model() {
    let art = std::env::temp_dir().join("ficabu_audit_attest_artifacts");
    std::env::set_var("FICABU_ARTIFACTS", &art);
    let opts = PrepareOpts { train_steps: 120, retrain: true, ..PrepareOpts::default() };
    let prep = exp::prepare("rn18slim", DatasetKind::Cifar20, &opts).unwrap();
    let cfg = exp::tables::mode_config(&prep, Mode::Ficabu, None);
    let wspec = WorkerSpec {
        meta: prep.model.meta.clone(),
        shared: SharedMeta::builtin(),
        params: prep.params,
        global: prep.global,
        train: prep.train,
        cfg,
        precision: prep.precision,
    };

    let dir =
        std::env::temp_dir().join(format!("ficabu_audit_attest_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let fleet = Fleet::start_durable(
        wspec,
        FleetConfig {
            workers: 1,
            queue_cap: 8,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
        DurabilityConfig { dir: dir.clone(), checkpoint_every: 1 },
    )
    .unwrap();
    let spec = ForgetSpec::Class(3);
    let sm = match fleet.submit(spec.clone()).recv().unwrap() {
        Reply::Done(sm) => sm,
        other => panic!("unexpected reply {other:?}"),
    };
    fleet.shutdown().unwrap();

    let at = sm.attest.as_ref().expect("a real forget carries an attestation");
    // Trained on class 3, its samples are member-like before the edit;
    // the pass makes them non-member-like. The attested member-rate
    // must strictly drop — this is the per-link unlearning evidence.
    assert!(
        at.mia_after < at.mia_before,
        "member-rate did not drop across the edit: {} -> {}",
        at.mia_before,
        at.mia_after
    );
    // Forgetting must not *improve* forget-set accuracy.
    assert!(
        sm.forget_acc <= at.forget_acc_before,
        "forget accuracy rose: {} -> {}",
        at.forget_acc_before,
        sm.forget_acc
    );

    // The same evidence is in the verified chain link, and `prove`
    // returns it for the executed spec.
    let report = audit::verify_dir(&dir).unwrap();
    assert_eq!(report.records.len(), 1);
    let link = report.records[0].attest.as_ref().expect("link embeds the attestation");
    assert_eq!(link, at);
    let links = audit::prove(&dir, None, &spec).unwrap();
    assert_eq!(links.len(), 1);

    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&art);
}
