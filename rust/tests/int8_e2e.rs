//! End-to-end acceptance for int8 serving: an int8-served FiCABU
//! unlearning event on a trained model reaches random-guess forget
//! accuracy with retain accuracy within 1 pp of the f32 path.
//!
//! In its own binary because it mutates `FICABU_ARTIFACTS` — tests that
//! touch the process environment get a dedicated process so no parallel
//! test reads the environment while it is being mutated (same rule as
//! `tests/gemm_threads_env.rs`). Trains for 120 steps like the
//! quickstart example, so this is the slowest test in the suite.

use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::runtime::Precision;

#[test]
fn int8_served_unlearning_matches_f32_quality() {
    let dir = std::env::temp_dir().join("ficabu_int8_e2e_artifacts");
    std::env::set_var("FICABU_ARTIFACTS", &dir);
    let opts = PrepareOpts { train_steps: 120, retrain: true, ..PrepareOpts::default() };
    let mut prep = exp::prepare("rn18slim", DatasetKind::Cifar20, &opts).unwrap();
    let tau = prep.kind.tau();
    let class = 3;
    let f32_res = exp::run_mode(&prep, class, Mode::Ficabu, None).unwrap();
    assert!(f32_res.df <= tau + 1e-9, "f32 forgetting missed target: {}", f32_res.df);

    // switch the same trained model to int8 serving
    let meta = prep.model.meta.clone();
    prep.params.quantize_int8(&meta);
    prep.precision = Precision::Int8;
    let i8_res = exp::run_mode(&prep, class, Mode::Ficabu, None).unwrap();
    let report = i8_res.report.as_ref().unwrap();
    assert_eq!(report.precision, Precision::Int8);
    assert!(i8_res.df <= tau + 1e-9, "int8 forgetting missed target: {}", i8_res.df);
    assert!(
        (i8_res.dr - f32_res.dr).abs() <= 0.01 + 1e-9,
        "int8 retain accuracy drifted beyond 1 pp: f32 {} vs int8 {}",
        f32_res.dr,
        i8_res.dr
    );
    std::fs::remove_dir_all(&dir).ok();
}
