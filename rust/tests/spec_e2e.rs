//! End-to-end coverage of the typed request surface: multi-class and
//! sample-level `ForgetSpec`s through `UnlearnSession` (builder +
//! `forget` + `serve_sequential`) and through the `Fleet` dispatcher
//! with spec-key coalescing — on untrained builtin models so the suite
//! stays fast and deterministic.

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::coordinator::{
    Fleet, FleetConfig, Pacing, Reply, UnlearnSession, WorkerSpec,
};
use ficabu::data::{cifar20_like, Dataset, DatasetCfg};
use ficabu::fisher::Importance;
use ficabu::model::{Model, ParamStore};
use ficabu::runtime::{Precision, Runtime};
use ficabu::unlearn::{Cau, ForgetSpec, Ssd, Strategy};

fn train_set() -> Dataset {
    let cfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
    cifar20_like(&cfg).0
}

fn session(strategy: impl Strategy + 'static, seed: u64) -> UnlearnSession {
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let model = Model::load(&rt, meta.clone()).unwrap();
    let params = ParamStore::init(&meta, seed);
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    UnlearnSession::builder()
        .model(model)
        .params(params)
        .global(global)
        .train(train_set())
        .strategy(strategy)
        .seed(seed)
        .build()
        .unwrap()
}

#[test]
fn session_forgets_a_multi_class_spec() {
    // alpha = 1 over the 1e-6 importance floor selects aggressively, so
    // the "parameters changed" assertion below is unambiguous
    let mut s = session(Ssd::new(1.0, 1.0), 42);
    let before = s.params.clone();
    let spec = ForgetSpec::Classes(vec![3, 1, 3]); // unsorted + dup on purpose
    let sm = s.forget(&spec).unwrap();
    assert_eq!(sm.spec, ForgetSpec::Classes(vec![1, 3]), "summary carries the canonical spec");
    assert!(sm.stop_depth.is_none(), "SSD has no early stop");
    assert!((0.0..=1.0).contains(&sm.forget_acc));
    assert!((0.0..=1.0).contains(&sm.retain_acc));
    assert!(sm.macs_vs_ssd_pct > 0.0 && sm.sim_energy_mj > 0.0);
    // the event actually edited the store
    let edited = before
        .seg
        .iter()
        .zip(&s.params.seg)
        .any(|(a, b)| a.iter().zip(b).any(|(ta, tb)| ta.data != tb.data));
    assert!(edited, "multi-class event must dampen parameters");
}

#[test]
fn session_forgets_a_sample_spec() {
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let mut s = session(Cau::new(10.0, 1.0, vec![1], 1.0), 7);
    // erase four specific samples of class 2 (tau = 1.0 stops at l = 1,
    // keeping the test cheap)
    let pool: Vec<usize> = s.train.class_indices(2).into_iter().take(4).collect();
    let sm = s.forget(&ForgetSpec::Samples(pool.clone())).unwrap();
    assert_eq!(sm.spec, ForgetSpec::Samples(pool));
    assert_eq!(sm.stop_depth, Some(1));
    // only the head segment may differ from a fresh init
    let fresh = ParamStore::init(&meta, 7);
    for k in 0..meta.num_segments() - 1 {
        for (a, b) in fresh.seg[k].iter().zip(&s.params.seg[k]) {
            assert_eq!(a.data, b.data, "segment {k} modified despite depth-1 stop");
        }
    }
}

#[test]
fn session_rejects_invalid_specs() {
    let mut s = session(Ssd::new(10.0, 1.0), 11);
    let n_classes = s.model.meta.num_classes;
    let n_samples = s.train.len();
    assert!(s.forget(&ForgetSpec::Class(n_classes)).is_err());
    assert!(s.forget(&ForgetSpec::Classes(vec![])).is_err());
    assert!(s.forget(&ForgetSpec::Classes(vec![0, n_classes])).is_err());
    assert!(s.forget(&ForgetSpec::Samples(vec![n_samples])).is_err());
}

#[test]
fn serve_sequential_times_every_spec() {
    let mut s = session(Cau::new(10.0, 1.0, vec![1], 1.0), 23);
    let pool: Vec<usize> = s.train.class_indices(4).into_iter().take(3).collect();
    let out = s.serve_sequential([
        ForgetSpec::Class(0),
        ForgetSpec::Classes(vec![2, 5]),
        ForgetSpec::Samples(pool),
    ]);
    assert_eq!(out.len(), 3);
    for r in &out {
        let sm = r.as_ref().expect("sequential serving succeeds");
        assert!(sm.timing.service_ms >= 0.0);
    }
    // a bad request reports, not panics, and later requests still run
    let out = s.serve_sequential([ForgetSpec::Class(999), ForgetSpec::Class(1)]);
    assert!(out[0].is_err());
    assert!(out[1].is_ok());
}

#[test]
fn fleet_serves_spec_diversity_with_coalescing() {
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let train = train_set();
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    let sample_pool: Vec<usize> = train.class_indices(6).into_iter().take(3).collect();
    let spec = WorkerSpec {
        meta: meta.clone(),
        shared: SharedMeta::builtin(),
        params: ParamStore::init(&meta, 5),
        global,
        train,
        // tau = 1.0 + head checkpoint: every event stops at depth 1
        cfg: Cau::new(10.0, 1.0, vec![1], 1.0).into_config(),
        precision: Precision::F32,
    };
    let fleet = Fleet::start(
        spec,
        FleetConfig {
            workers: 1, // single worker: the queue backs up, so equal keys coalesce
            queue_cap: 16,
            deadline: None,
            batch_max: 2,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
    )
    .unwrap();

    let submissions = [
        ForgetSpec::Class(0),
        ForgetSpec::Classes(vec![4, 1]),
        ForgetSpec::Classes(vec![1, 4, 4]), // coalesces with the line above (if still queued)
        ForgetSpec::Samples(sample_pool.clone()),
        ForgetSpec::Samples(sample_pool),
    ];
    let rxs: Vec<_> = submissions.iter().cloned().map(|s| fleet.submit(s)).collect();
    for (sub, rx) in submissions.iter().zip(rxs) {
        match rx.recv().unwrap() {
            Reply::Done(sm) => {
                assert_eq!(sm.spec, sub.canonical(), "reply routed by canonical key");
                assert_eq!(sm.stop_depth, Some(1));
            }
            other => panic!("{sub}: unexpected reply {other:?}"),
        }
    }
    let stats = fleet.shutdown().unwrap();
    let total = stats.merged();
    assert_eq!(
        total.served + stats.coalesced,
        5,
        "every request executed or coalesced (served {}, coalesced {})",
        total.served,
        stats.coalesced
    );
    assert_eq!(total.failures, 0);
}
