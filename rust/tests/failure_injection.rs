//! Failure injection: the coordinator must fail loudly and safely on
//! malformed metadata, shape mismatches, wrong module arity, and
//! corrupted persisted state — an edge device cannot page an operator.
//!
//! All paths run on the default CpuBackend; the artifact-specific
//! failure modes (truncated HLO text) belong to the `backend-xla`
//! feature and are exercised there.

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::fisher::Importance;
use ficabu::model::{Model, ParamStore};
use ficabu::runtime::{ModuleSpec, Runtime};
use ficabu::tensor::Tensor;
use ficabu::util::json::Json;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ficabu_fi_{name}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn shared() -> SharedMeta {
    SharedMeta::builtin()
}

#[test]
fn meta_with_missing_keys_is_rejected() {
    let dir = tmpdir("meta");
    std::fs::write(dir.join("meta.json"), r#"{"name": "x"}"#).unwrap();
    assert!(ModelMeta::load(&dir).is_err());
    // malformed json
    std::fs::write(dir.join("meta.json"), "{ nope").unwrap();
    assert!(ModelMeta::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shared_meta_missing_dir_is_rejected() {
    assert!(SharedMeta::load("/nonexistent/shared").is_err());
}

#[test]
fn unknown_builtin_model_is_rejected() {
    assert!(ModelMeta::builtin("vgg16").is_err());
    assert!(ModelMeta::resolve("vgg16").is_err());
}

#[test]
fn wrong_arity_execution_fails_not_crashes() {
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&ModuleSpec::Fimd { shared: shared() }).unwrap();
    // fimd takes 3 args; give it 1 — must be an Err, not a panic
    let t = Tensor::vec1(vec![0.0; shared().tile]);
    assert!(exe.run(&[&t]).is_err());
}

#[test]
fn wrong_shape_execution_fails_not_crashes() {
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&ModuleSpec::Fimd { shared: shared() }).unwrap();
    let wrong = Tensor::vec1(vec![0.0; 16]); // tile is 8192
    let acc = Tensor::vec1(vec![0.0; 16]);
    let s = Tensor::vec1(vec![1.0]);
    assert!(exe.run(&[&wrong, &acc, &s]).is_err());
}

#[test]
fn gemm_inner_dim_mismatch_rejected() {
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&ModuleSpec::Gemm { shared: shared() }).unwrap();
    let x = Tensor::new(vec![2, 3], vec![0.0; 6]).unwrap();
    let y = Tensor::new(vec![4, 2], vec![0.0; 8]).unwrap();
    assert!(exe.run(&[&x, &y]).is_err());
}

#[test]
fn segment_module_rejects_bad_input_shape() {
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let exe = rt
        .load(&ModuleSpec::SegmentFwd { meta: meta.clone(), seg: 0 })
        .unwrap();
    let params = ParamStore::init(&meta, 1);
    let mut args: Vec<&Tensor> = params.seg[0].iter().collect();
    // stem wants [B, 32, 32, 3]; hand it a flat vector
    let bad = Tensor::vec1(vec![0.0; 3072]);
    args.push(&bad);
    assert!(exe.run(&args).is_err());
}

#[test]
fn params_shape_mismatch_detected_by_validate() {
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let mut ps = ParamStore::init(&meta, 1);
    // corrupt one tensor's shape
    ps.seg[0][0] = Tensor::zeros(vec![1, 2, 3]);
    assert!(ps.validate(&meta).is_err());
}

#[test]
fn model_load_with_unknown_segment_kind_errors() {
    let rt = Runtime::cpu().unwrap();
    let mut meta = ModelMeta::builtin("rn18slim").unwrap();
    meta.segments[0].kind = "deconv".into();
    assert!(Model::load(&rt, meta).is_err());
}

#[test]
fn inconsistent_meta_geometry_rejected_not_panicking() {
    let rt = Runtime::cpu().unwrap();
    let mut meta = ModelMeta::builtin("rn18slim").unwrap();
    // stem claims a 5-input-channel kernel against a 3-channel input:
    // must be an Err at compile, never an out-of-bounds slice at run
    meta.segments[0].params[0].shape = vec![3, 3, 5, 8];
    assert!(rt
        .load(&ModuleSpec::SegmentFwd { meta: meta.clone(), seg: 0 })
        .is_err());
    // declared out_shape disagreeing with the conv geometry is also an Err
    let mut meta2 = ModelMeta::builtin("rn18slim").unwrap();
    meta2.segments[0].out_shape = vec![16, 16, 8];
    assert!(rt.load(&ModuleSpec::SegmentFwd { meta: meta2, seg: 0 }).is_err());
}

#[test]
fn encoder_meta_with_zero_heads_rejected() {
    let rt = Runtime::cpu().unwrap();
    let mut meta = ModelMeta::builtin("vitslim").unwrap();
    meta.heads = 0;
    assert!(rt.load(&ModuleSpec::SegmentFwd { meta, seg: 1 }).is_err());
}

#[test]
fn model_load_with_inconsistent_block_inventory_errors() {
    let rt = Runtime::cpu().unwrap();
    let mut meta = ModelMeta::builtin("rn18slim").unwrap();
    // s2b1 is a downsampling block (9 params); drop its shortcut params
    meta.segments[3].params.truncate(6);
    assert!(Model::load(&rt, meta).is_err());
}

#[test]
fn corrupt_checkpoint_rejected() {
    let dir = tmpdir("bad_ckpt");
    let path = dir.join("bad.fcb");
    std::fs::write(&path, b"NOTMAGIC").unwrap();
    assert!(ParamStore::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_importance_file_rejected() {
    let dir = tmpdir("bad_imp");
    let path = dir.join("bad.imp");
    std::fs::write(&path, b"FICABIM1\xff\xff\xff\xff").unwrap();
    assert!(Importance::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn json_emitter_roundtrips_report_like_structures() {
    // emission path used by run reports: nested obj/arr with floats
    let j = Json::obj(vec![
        ("dr", Json::Num(0.9836)),
        ("selected", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
        ("mode", Json::Str("ficabu".into())),
        ("stop", Json::Null),
    ]);
    let s = j.to_string();
    let back = Json::parse(&s).unwrap();
    assert_eq!(back.get("dr").unwrap().as_f64(), Some(0.9836));
    assert_eq!(back.get("stop"), Some(&Json::Null));
}
