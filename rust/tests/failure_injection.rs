//! Failure injection: the coordinator must fail loudly and safely on
//! corrupted artifacts, malformed metadata, and shape mismatches — an
//! edge device cannot page an operator.

use std::path::{Path, PathBuf};

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::model::{Model, ParamStore};
use ficabu::runtime::Runtime;
use ficabu::tensor::Tensor;
use ficabu::util::json::Json;

fn art() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("artifacts")
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ficabu_fi_{name}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn truncated_hlo_module_is_rejected_at_load() {
    let rt = Runtime::cpu().unwrap();
    let src = art().join("shared").join("fimd.hlo.txt");
    let text = std::fs::read_to_string(&src).unwrap();
    let dir = tmpdir("trunc");
    let bad = dir.join("fimd.hlo.txt");
    std::fs::write(&bad, &text[..text.len() / 3]).unwrap();
    assert!(rt.load(&bad).is_err(), "truncated HLO must not compile");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn garbage_hlo_module_is_rejected() {
    let rt = Runtime::cpu().unwrap();
    let dir = tmpdir("garbage");
    let bad = dir.join("x.hlo.txt");
    std::fs::write(&bad, "this is not an hlo module at all {{{").unwrap();
    assert!(rt.load(&bad).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn meta_with_missing_keys_is_rejected() {
    let dir = tmpdir("meta");
    std::fs::write(dir.join("meta.json"), r#"{"name": "x"}"#).unwrap();
    assert!(ModelMeta::load(&dir).is_err());
    // malformed json
    std::fs::write(dir.join("meta.json"), "{ nope").unwrap();
    assert!(ModelMeta::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shared_meta_missing_dir_is_rejected() {
    assert!(SharedMeta::load("/nonexistent/shared").is_err());
}

#[test]
fn wrong_arity_execution_fails_not_crashes() {
    let rt = Runtime::cpu().unwrap();
    let shared = SharedMeta::load(art().join("shared")).unwrap();
    let exe = rt.load(shared.module_path(&shared.fimd)).unwrap();
    // fimd takes 3 args; give it 1 — must be an Err, not a segfault
    let t = Tensor::vec1(vec![0.0; shared.tile]);
    assert!(exe.run(&[&t]).is_err());
}

#[test]
fn wrong_shape_execution_fails_not_crashes() {
    let rt = Runtime::cpu().unwrap();
    let shared = SharedMeta::load(art().join("shared")).unwrap();
    let exe = rt.load(shared.module_path(&shared.fimd)).unwrap();
    let wrong = Tensor::vec1(vec![0.0; 16]); // tile is 8192
    let acc = Tensor::vec1(vec![0.0; 16]);
    let s = Tensor::vec1(vec![1.0]);
    assert!(exe.run(&[&wrong, &acc, &s]).is_err());
}

#[test]
fn params_shape_mismatch_detected_by_validate() {
    let meta = ModelMeta::load(art().join("rn18slim")).unwrap();
    let mut ps = ParamStore::init(&meta, 1);
    // corrupt one tensor's shape
    ps.seg[0][0] = Tensor::zeros(vec![1, 2, 3]);
    assert!(ps.validate(&meta).is_err());
}

#[test]
fn model_load_with_missing_module_file_errors() {
    let rt = Runtime::cpu().unwrap();
    let mut meta = ModelMeta::load(art().join("rn18slim")).unwrap();
    meta.segments[0].fwd = "does_not_exist.hlo.txt".into();
    assert!(Model::load(&rt, meta).is_err());
}

#[test]
fn json_emitter_roundtrips_report_like_structures() {
    // emission path used by run reports: nested obj/arr with floats
    let j = Json::obj(vec![
        ("dr", Json::Num(0.9836)),
        ("selected", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
        ("mode", Json::Str("ficabu".into())),
        ("stop", Json::Null),
    ]);
    let s = j.to_string();
    let back = Json::parse(&s).unwrap();
    assert_eq!(back.get("dr").unwrap().as_f64(), Some(0.9836));
    assert_eq!(back.get("stop"), Some(&Json::Null));
}
