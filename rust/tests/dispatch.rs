//! Dispatcher behavior tests against a mock [`UnlearnService`] — no
//! model math, so spec-key coalescing, shedding, drain, and the stats
//! rollup are exercised deterministically.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use ficabu::coordinator::wal::{self, Disposition, Record};
use ficabu::coordinator::{
    DurabilityConfig, Fleet, FleetConfig, ModelId, Pacing, QueueStats, Reply, Summary, Timing,
    UnlearnService,
};
use ficabu::testkit::faults;
use ficabu::unlearn::ForgetSpec;

/// Mock worker core. Every `unlearn` call announces `(worker, spec)` on
/// `started`, then blocks until the test feeds one token through `gate`.
/// `class:13` fails after the gate (exercises the failure path);
/// `class:66` panics after the gate (exercises panic isolation).
struct MockService {
    wid: usize,
    started: Sender<(usize, ForgetSpec)>,
    gate: Arc<Mutex<Receiver<()>>>,
    log: Arc<Mutex<Vec<(usize, ForgetSpec)>>>,
}

fn mock_summary(spec: &ForgetSpec) -> Summary {
    Summary {
        model: ModelId::default(),
        config_hash: 0,
        spec: spec.clone(),
        forget_acc: 0.0,
        retain_acc: 1.0,
        stop_depth: Some(1),
        macs_vs_ssd_pct: 1.0,
        sim_energy_mj: 0.1,
        sim_energy_vs_ssd_pct: 1.0,
        sim_ms: 0.0,
        rolled_back: false,
        timing: Timing::default(),
        wal_seq: None,
        attest: None,
    }
}

impl UnlearnService for MockService {
    fn unlearn(&mut self, spec: &ForgetSpec) -> anyhow::Result<Summary> {
        let _ = self.started.send((self.wid, spec.clone()));
        self.gate
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| anyhow::anyhow!("gate closed"))?;
        self.log.lock().unwrap().push((self.wid, spec.clone()));
        if *spec == ForgetSpec::Class(13) {
            anyhow::bail!("boom on class 13");
        }
        if *spec == ForgetSpec::Class(66) {
            panic!("mock engine panicked on class 66");
        }
        Ok(mock_summary(spec))
    }
}

struct Rig {
    started: Receiver<(usize, ForgetSpec)>,
    tokens: Sender<()>,
    log: Arc<Mutex<Vec<(usize, ForgetSpec)>>>,
}

/// Build a fleet of mock workers plus the test-side controls.
fn mock_fleet(cfg: FleetConfig) -> (Fleet, Rig) {
    let (started_tx, started_rx) = channel();
    let (token_tx, token_rx) = channel();
    let gate = Arc::new(Mutex::new(token_rx));
    let log = Arc::new(Mutex::new(Vec::new()));
    let log2 = Arc::clone(&log);
    let fleet = Fleet::start_with(cfg, move |wid| {
        Ok(MockService {
            wid,
            started: started_tx.clone(),
            gate: Arc::clone(&gate),
            log: Arc::clone(&log2),
        })
    })
    .expect("mock fleet starts");
    (fleet, Rig { started: started_rx, tokens: token_tx, log })
}

fn executions_of(rig: &Rig, spec: &ForgetSpec) -> usize {
    let key = spec.key();
    let log = rig.log.lock().unwrap();
    log.iter().filter(|(_, s)| s.key() == key).count()
}

const STARTED_TIMEOUT: Duration = Duration::from_secs(10);

#[test]
fn coalescing_fans_out_one_execution() {
    let (fleet, rig) = mock_fleet(FleetConfig {
        workers: 1,
        queue_cap: 8,
        deadline: None,
        batch_max: 1,
        pacing: Pacing::Host,
        respawn_giveup: 5,
    });

    // Occupy the single worker so subsequent submissions stay queued.
    let rx7 = fleet.submit(ForgetSpec::Class(7));
    let (w, s) = rig.started.recv_timeout(STARTED_TIMEOUT).unwrap();
    assert_eq!((w, s), (0, ForgetSpec::Class(7)));

    // k identical requests while the worker is busy: the first opens a
    // queue entry, the other four coalesce onto it.
    let dup_rxs: Vec<_> = (0..5).map(|_| fleet.submit(ForgetSpec::Class(3))).collect();

    // Two tokens: finish class 7, then the single coalesced class-3 run.
    rig.tokens.send(()).unwrap();
    rig.tokens.send(()).unwrap();

    match rx7.recv().unwrap() {
        Reply::Done(s) => assert_eq!(s.spec, ForgetSpec::Class(7)),
        other => panic!("class 7: unexpected reply {other:?}"),
    }
    for rx in dup_rxs {
        match rx.recv().unwrap() {
            Reply::Done(s) => {
                // every coalesced requester gets the same execution
                assert_eq!(s.spec, ForgetSpec::Class(3));
                assert!(s.timing.service_ms >= 0.0);
            }
            other => panic!("class 3: unexpected reply {other:?}"),
        }
    }
    assert_eq!(
        executions_of(&rig, &ForgetSpec::Class(3)),
        1,
        "5 duplicate requests -> 1 execution"
    );
    assert_eq!(executions_of(&rig, &ForgetSpec::Class(7)), 1);

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.admitted, 2);
    assert_eq!(stats.coalesced, 4);
    let total = stats.merged();
    assert_eq!(total.served, 2);
    assert_eq!(total.failures, 0);
}

#[test]
fn equivalent_specs_coalesce_across_variants() {
    let (fleet, rig) = mock_fleet(FleetConfig {
        workers: 1,
        queue_cap: 8,
        deadline: None,
        batch_max: 1,
        pacing: Pacing::Host,
        respawn_giveup: 5,
    });

    // Stall the worker so everything below queues.
    let rx0 = fleet.submit(ForgetSpec::Class(0));
    rig.started.recv_timeout(STARTED_TIMEOUT).unwrap();

    // One canonical multi-class event, requested three different ways.
    let rx_a = fleet.submit(ForgetSpec::Classes(vec![4, 1]));
    let rx_b = fleet.submit(ForgetSpec::Classes(vec![1, 4, 4]));
    // A single-id Classes collapses onto the equivalent Class entry...
    let rx_c = fleet.submit(ForgetSpec::Class(9));
    let rx_d = fleet.submit(ForgetSpec::Classes(vec![9]));
    // ...but the same ids as *samples* are a distinct request.
    let rx_e = fleet.submit(ForgetSpec::Samples(vec![1, 4]));

    // 4 executions total: class 0, classes{1,4}, class 9, samples{1,4}.
    for _ in 0..4 {
        rig.tokens.send(()).unwrap();
    }
    for (rx, want) in [
        (rx0, ForgetSpec::Class(0)),
        (rx_a, ForgetSpec::Classes(vec![1, 4])),
        (rx_b, ForgetSpec::Classes(vec![1, 4])),
        (rx_c, ForgetSpec::Class(9)),
        (rx_d, ForgetSpec::Class(9)),
        (rx_e, ForgetSpec::Samples(vec![1, 4])),
    ] {
        match rx.recv().unwrap() {
            Reply::Done(s) => assert_eq!(s.spec, want, "summary routes the canonical spec"),
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert_eq!(executions_of(&rig, &ForgetSpec::Classes(vec![1, 4])), 1);
    assert_eq!(executions_of(&rig, &ForgetSpec::Class(9)), 1);
    assert_eq!(executions_of(&rig, &ForgetSpec::Samples(vec![1, 4])), 1);

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.admitted, 4);
    assert_eq!(stats.coalesced, 2);
    assert_eq!(stats.merged().served, 4);
}

#[test]
fn bounded_queue_sheds_with_backpressure() {
    let (fleet, rig) = mock_fleet(FleetConfig {
        workers: 1,
        queue_cap: 2,
        deadline: None,
        batch_max: 1,
        pacing: Pacing::Host,
        respawn_giveup: 5,
    });

    // Stall the worker on class 0; fill the queue with classes 1 and 2.
    let rx0 = fleet.submit(ForgetSpec::Class(0));
    rig.started.recv_timeout(STARTED_TIMEOUT).unwrap();
    let rx1 = fleet.submit(ForgetSpec::Class(1));
    let rx2 = fleet.submit(ForgetSpec::Class(2));

    // The queue is full: a distinct spec is shed immediately.
    let rx3 = fleet.submit(ForgetSpec::Class(3));
    match rx3.recv_timeout(Duration::from_secs(1)).unwrap() {
        Reply::Backpressure { queue_len, queue_cap } => {
            assert_eq!(queue_len, 2);
            assert_eq!(queue_cap, 2);
        }
        other => panic!("expected backpressure, got {other:?}"),
    }
    // ... but an equivalent of a *queued* spec still coalesces: the
    // queue doesn't grow, so coalescing beats shedding under overload.
    let rx1b = fleet.submit(ForgetSpec::Classes(vec![1]));

    for _ in 0..3 {
        rig.tokens.send(()).unwrap();
    }
    for rx in [rx0, rx1, rx2, rx1b] {
        match rx.recv().unwrap() {
            Reply::Done(_) => {}
            other => panic!("unexpected reply {other:?}"),
        }
    }

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.admitted, 3);
    assert_eq!(stats.coalesced, 1);
    assert_eq!(stats.shed_backpressure, 1);
    assert_eq!(stats.merged().served, 3);
}

#[test]
fn shutdown_drains_deterministically() {
    let (fleet, rig) = mock_fleet(FleetConfig {
        workers: 2,
        queue_cap: 16,
        deadline: None,
        batch_max: 2,
        pacing: Pacing::Host,
        respawn_giveup: 5,
    });

    // Pre-feed tokens so workers never block; submit six distinct
    // specs and shut down immediately: every admitted request must
    // still be answered before the workers exit.
    for _ in 0..6 {
        rig.tokens.send(()).unwrap();
    }
    let rxs: Vec<_> = (0..6).map(|c| fleet.submit(ForgetSpec::Class(c))).collect();
    let stats = fleet.shutdown().unwrap();

    for (c, rx) in rxs.into_iter().enumerate() {
        match rx.recv().unwrap() {
            Reply::Done(s) => assert_eq!(s.spec, ForgetSpec::Class(c)),
            other => panic!("class {c}: unexpected reply {other:?}"),
        }
    }
    assert_eq!(stats.admitted, 6);
    assert_eq!(stats.queue_depth, 0, "drained queue");
    let total = stats.merged();
    assert_eq!(total.served, 6);
    // per-worker -> fleet rollup arithmetic
    assert_eq!(stats.per_worker.len(), 2);
    let by_hand: u64 = stats.per_worker.iter().map(|w| w.served).sum();
    assert_eq!(total.served, by_hand);
    let hist_total: u64 = stats.per_worker.iter().map(|w| w.service_hist.count()).sum();
    assert_eq!(total.service_hist.count(), hist_total);
    assert_eq!(total.batches, stats.per_worker.iter().map(|w| w.batches).sum::<u64>());
    assert!(total.max_batch <= 2, "batch_max respected");
    assert!(total.batches >= 3, "6 requests in passes of <= 2");
}

#[test]
fn stalled_worker_deadline_sheds_expired_entries() {
    let (fleet, rig) = mock_fleet(FleetConfig {
        workers: 1,
        queue_cap: 8,
        deadline: None,
        batch_max: 1,
        pacing: Pacing::Host,
        respawn_giveup: 5,
    });

    // Stall the worker, then queue a request with a deadline it cannot
    // meet while stalled.
    let rx0 = fleet.submit(ForgetSpec::Class(0));
    rig.started.recv_timeout(STARTED_TIMEOUT).unwrap();
    let rx1 =
        fleet.submit_with_deadline(ForgetSpec::Class(1), Some(Duration::from_millis(5)));
    std::thread::sleep(Duration::from_millis(30));

    // Unstall: class 0 completes; class 1 is claimed past its deadline
    // and shed without touching the engine.
    rig.tokens.send(()).unwrap();
    match rx0.recv().unwrap() {
        Reply::Done(_) => {}
        other => panic!("unexpected reply {other:?}"),
    }
    match rx1.recv().unwrap() {
        Reply::Expired { missed_by_ms } => assert!(missed_by_ms > 0.0),
        other => panic!("expected expired, got {other:?}"),
    }
    assert_eq!(
        executions_of(&rig, &ForgetSpec::Class(1)),
        0,
        "shed requests never execute"
    );

    let stats = fleet.shutdown().unwrap();
    let total = stats.merged();
    assert_eq!(total.shed_deadline, 1);
    assert_eq!(total.served, 1);
    // sheds never reached the engine, so they stay out of the latency
    // aggregates
    assert_eq!(total.completed(), 1);
    assert_eq!(total.service_hist.count(), 1);
}

#[test]
fn failed_requests_reply_and_count_into_timing() {
    let (fleet, rig) = mock_fleet(FleetConfig {
        workers: 1,
        queue_cap: 8,
        deadline: None,
        batch_max: 4,
        pacing: Pacing::Host,
        respawn_giveup: 5,
    });

    rig.tokens.send(()).unwrap();
    rig.tokens.send(()).unwrap();
    let rx_ok = fleet.submit(ForgetSpec::Class(2));
    let rx_bad = fleet.submit(ForgetSpec::Class(13)); // mock fails on 13

    match rx_ok.recv().unwrap() {
        Reply::Done(s) => assert_eq!(s.spec, ForgetSpec::Class(2)),
        other => panic!("unexpected reply {other:?}"),
    }
    match rx_bad.recv().unwrap() {
        Reply::Failed(msg) => assert!(msg.contains("boom"), "got: {msg}"),
        other => panic!("expected failure, got {other:?}"),
    }

    let stats = fleet.shutdown().unwrap();
    let total = stats.merged();
    assert_eq!(total.served, 1);
    assert_eq!(total.failures, 1);
    // the failed request's latency is visible in the aggregates
    assert_eq!(total.completed(), 2);
    assert_eq!(total.service_hist.count(), 2);
    assert_eq!(total.queue_hist.count(), 2);
}

#[test]
fn worker_startup_failure_fails_fast() {
    let out = Fleet::start_with(
        FleetConfig { workers: 2, ..FleetConfig::default() },
        |wid| -> anyhow::Result<NeverService> {
            if wid == 1 {
                anyhow::bail!("no model for worker {wid}");
            }
            Ok(NeverService)
        },
    );
    let err = match out {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("startup must fail when a worker cannot build"),
    };
    assert!(err.contains("no model"), "got: {err}");
}

struct NeverService;

impl UnlearnService for NeverService {
    fn unlearn(&mut self, _spec: &ForgetSpec) -> anyhow::Result<Summary> {
        unreachable!("never dispatched")
    }
}

#[test]
fn panic_is_isolated_and_worker_respawns() {
    let (fleet, rig) = mock_fleet(FleetConfig {
        workers: 1,
        queue_cap: 8,
        deadline: None,
        batch_max: 4,
        pacing: Pacing::Host,
        respawn_giveup: 5,
    });

    // Stall the worker on class 0, then queue a poison request (the
    // mock panics on class 66) followed by two healthy ones.
    let rx0 = fleet.submit(ForgetSpec::Class(0));
    rig.started.recv_timeout(STARTED_TIMEOUT).unwrap();
    let rx66 = fleet.submit(ForgetSpec::Class(66));
    let rx1 = fleet.submit(ForgetSpec::Class(1));
    let rx2 = fleet.submit(ForgetSpec::Class(2));
    for _ in 0..4 {
        rig.tokens.send(()).unwrap();
    }

    match rx0.recv().unwrap() {
        Reply::Done(s) => assert_eq!(s.spec, ForgetSpec::Class(0)),
        other => panic!("class 0: unexpected reply {other:?}"),
    }
    // The poisoned request is answered, not hung: its reply names the
    // panic instead of dropping the sender.
    match rx66.recv().unwrap() {
        Reply::Failed(msg) => {
            assert!(msg.contains("panicked"), "got: {msg}");
            assert!(msg.contains("class 66"), "payload text travels: {msg}");
        }
        other => panic!("class 66: expected failure, got {other:?}"),
    }
    // The rest of the panicked worker's claimed batch is re-queued and
    // served by the respawned replica — nothing is lost with it.
    for (rx, c) in [(rx1, 1), (rx2, 2)] {
        match rx.recv().unwrap() {
            Reply::Done(s) => assert_eq!(s.spec, ForgetSpec::Class(c)),
            other => panic!("class {c}: unexpected reply {other:?}"),
        }
    }
    let live = fleet.stats();
    assert_eq!(live.alive, 1, "respawned worker is alive again");

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.admitted, 4);
    let total = stats.merged();
    assert_eq!(total.served, 3);
    assert_eq!(total.failures, 1, "the in-flight request counts as a failure");
    assert_eq!(total.panics, 1);
    assert_eq!(total.respawns, 1);
}

#[test]
fn dead_fleet_fails_fast_after_respawn_gives_up() {
    // One replica that panics on every request, and a factory with no
    // spare: the single respawnable build is the initial one.
    let builds = Arc::new(AtomicUsize::new(0));
    let b = Arc::clone(&builds);
    let fleet = Fleet::start_with(
        FleetConfig { workers: 1, respawn_giveup: 2, ..FleetConfig::default() },
        move |_wid| {
            if b.fetch_add(1, Ordering::SeqCst) == 0 {
                Ok(AlwaysPanics)
            } else {
                anyhow::bail!("no spare replica")
            }
        },
    )
    .unwrap();

    let rx = fleet.submit(ForgetSpec::Class(1));
    match rx.recv().unwrap() {
        Reply::Failed(msg) => assert!(msg.contains("panicked"), "got: {msg}"),
        other => panic!("expected failure, got {other:?}"),
    }

    // Respawn tries `respawn_giveup` times (one initial build + two
    // retries = 3 factory calls), then declares the worker dead.
    let t0 = std::time::Instant::now();
    while fleet.stats().alive != 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never died");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(builds.load(Ordering::SeqCst), 3);

    // A dead fleet fails at admission instead of queueing forever.
    let rx = fleet.submit(ForgetSpec::Class(2));
    match rx.recv_timeout(Duration::from_secs(1)).unwrap() {
        Reply::Failed(msg) => assert!(msg.contains("no live fleet workers"), "got: {msg}"),
        other => panic!("expected failure, got {other:?}"),
    }

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.alive, 0);
    assert_eq!(stats.admitted, 1, "the dead-fleet submission is never admitted");
    let total = stats.merged();
    assert_eq!(total.panics, 1);
    assert_eq!(total.respawns, 0, "give-up means no successful respawn");
}

struct AlwaysPanics;

impl UnlearnService for AlwaysPanics {
    fn unlearn(&mut self, _spec: &ForgetSpec) -> anyhow::Result<Summary> {
        panic!("replica poisoned")
    }
}

// --- durability ---------------------------------------------------------
//
// Fault sites are process-global, so the durable tests (one of which arms
// a `wal_append` fault) serialize among themselves: a concurrently
// running durable test would otherwise steal the armed fault's first hit.
static DURABLE_SERIAL: Mutex<()> = Mutex::new(());

fn durable_dir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ficabu_dispatch_wal_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// `mock_fleet` over a durable start: same controls, plus a ledger.
fn mock_fleet_durable(cfg: FleetConfig, dcfg: DurabilityConfig) -> (Fleet, Rig) {
    let (started_tx, started_rx) = channel();
    let (token_tx, token_rx) = channel();
    let gate = Arc::new(Mutex::new(token_rx));
    let log = Arc::new(Mutex::new(Vec::new()));
    let log2 = Arc::clone(&log);
    let fleet = Fleet::start_with_durable(
        cfg,
        move |wid| {
            Ok(MockService {
                wid,
                started: started_tx.clone(),
                gate: Arc::clone(&gate),
                log: Arc::clone(&log2),
            })
        },
        dcfg,
    )
    .expect("durable mock fleet starts");
    (fleet, Rig { started: started_rx, tokens: token_tx, log })
}

#[test]
fn durable_fleet_ledgers_completions_and_replays_after_crash() {
    let _serial = DURABLE_SERIAL.lock().unwrap();
    faults::clear();
    let dir = durable_dir("replay");
    let dcfg = DurabilityConfig { dir: dir.clone(), checkpoint_every: 1 };
    let cfg = FleetConfig {
        workers: 1,
        queue_cap: 8,
        deadline: None,
        batch_max: 1,
        pacing: Pacing::Host,
        respawn_giveup: 5,
    };

    // Run 1: one success, one engine failure, clean shutdown.
    {
        let (fleet, rig) = mock_fleet_durable(cfg.clone(), dcfg.clone());
        rig.tokens.send(()).unwrap();
        rig.tokens.send(()).unwrap();
        let rx_ok = fleet.submit(ForgetSpec::Class(2));
        let rx_bad = fleet.submit(ForgetSpec::Class(13)); // mock fails on 13
        match rx_ok.recv().unwrap() {
            Reply::Done(s) => {
                assert_eq!(s.wal_seq, Some(1), "summary carries its ledger seq");
            }
            other => panic!("unexpected reply {other:?}"),
        }
        match rx_bad.recv().unwrap() {
            Reply::Failed(msg) => assert!(msg.contains("boom"), "got: {msg}"),
            other => panic!("expected failure, got {other:?}"),
        }
        let stats = fleet.shutdown().unwrap();
        let dur = stats.durability.expect("durable fleet reports durability stats");
        assert_eq!(dur.generation, 1);
        assert_eq!(dur.wal_seq, 2);
        assert_eq!(dur.replayed, 0);
        assert_eq!(dur.checkpoints, 0, "mock service has no params to checkpoint");
    }

    // Simulate a crash after admission: an `Accepted` record with no
    // `Completed` (exactly what a kill between fsync and the pass leaves).
    {
        let (w, _tail) = wal::Wal::open_append(dir.join(wal::LEDGER_FILE)).unwrap();
        w.append_accepted(&ModelId::default(), &ForgetSpec::Class(5), 0, None).unwrap();
    }

    // Run 2: recovery replays the unfinished entry AND the completed-but-
    // uncovered one (no checkpoint ever covered seq 1), never the failure
    // (the engine rolled it back; there is nothing to restore).
    let (fleet, rig) = mock_fleet_durable(cfg, dcfg);
    let dur = fleet.stats().durability.unwrap();
    assert_eq!(dur.replayed, 2, "done-but-uncovered + accepted-only");
    assert_eq!(dur.generation, 2, "recovery bumps the ledger generation");

    rig.tokens.send(()).unwrap();
    rig.tokens.send(()).unwrap();
    let (_, s1) = rig.started.recv_timeout(STARTED_TIMEOUT).unwrap();
    let (_, s2) = rig.started.recv_timeout(STARTED_TIMEOUT).unwrap();
    assert_eq!((s1, s2), (ForgetSpec::Class(2), ForgetSpec::Class(5)), "ledger order");

    // New work resumes numbering after the renumbered replay set.
    rig.tokens.send(()).unwrap();
    let rx = fleet.submit(ForgetSpec::Class(6));
    match rx.recv().unwrap() {
        Reply::Done(s) => assert_eq!(s.wal_seq, Some(3)),
        other => panic!("unexpected reply {other:?}"),
    }

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.admitted, 3, "2 replayed + 1 new");
    assert_eq!(stats.merged().served, 3);
    let dur = stats.durability.unwrap();
    assert_eq!(dur.wal_seq, 3);

    // The rewritten ledger is a complete audit: every accepted entry has
    // a matching `Done` completion.
    let scan = wal::read_ledger(&dir.join(wal::LEDGER_FILE)).unwrap();
    assert_eq!(scan.generation, 2);
    assert!(!scan.truncated);
    let accepted: Vec<_> = scan
        .records
        .iter()
        .filter_map(|r| match r {
            Record::Accepted { seq, spec, .. } => Some((*seq, spec.clone())),
            _ => None,
        })
        .collect();
    assert_eq!(
        accepted,
        vec![
            (1, ForgetSpec::Class(2)),
            (2, ForgetSpec::Class(5)),
            (3, ForgetSpec::Class(6)),
        ]
    );
    let done: Vec<u64> = scan
        .records
        .iter()
        .filter_map(|r| match r {
            Record::Completed { seq, disposition: Disposition::Done, .. } => Some(*seq),
            _ => None,
        })
        .collect();
    assert_eq!(done, vec![1, 2, 3]);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn durable_admission_fails_closed_on_ledger_error() {
    let _serial = DURABLE_SERIAL.lock().unwrap();
    faults::clear();
    let dir = durable_dir("fail_closed");
    let (fleet, rig) = mock_fleet_durable(
        FleetConfig {
            workers: 1,
            queue_cap: 8,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
        DurabilityConfig { dir: dir.clone(), checkpoint_every: 1 },
    );

    // First ledger append errors: the request must fail closed — no
    // queue slot without a durable `Accepted` record.
    faults::arm("wal_append:1:error").unwrap();
    let rx = fleet.submit(ForgetSpec::Class(1));
    match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
        Reply::Failed(msg) => assert!(msg.contains("injected fault"), "got: {msg}"),
        other => panic!("expected fail-closed reply, got {other:?}"),
    }
    assert_eq!(
        executions_of(&rig, &ForgetSpec::Class(1)),
        0,
        "a request refused by the ledger never reaches the engine"
    );
    faults::clear();

    // With the ledger healthy again the same request goes through.
    rig.tokens.send(()).unwrap();
    let rx = fleet.submit(ForgetSpec::Class(1));
    match rx.recv().unwrap() {
        Reply::Done(s) => assert_eq!(s.wal_seq, Some(1), "the refused attempt burned no seq"),
        other => panic!("unexpected reply {other:?}"),
    }

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.admitted, 1, "the fail-closed submission was never admitted");
    assert_eq!(stats.durability.unwrap().wal_seq, 1);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn model_addressed_submission_on_a_single_model_fleet() {
    let (fleet, rig) = mock_fleet(FleetConfig::default());
    rig.tokens.send(()).unwrap();

    // the default id addresses the fleet's only model; the reply's
    // tenancy fields come from the batch key, not the service
    let rx = fleet.submit_to(ModelId::default(), ForgetSpec::Class(3), None);
    match rx.recv().unwrap() {
        Reply::Done(s) => {
            assert_eq!(s.model, ModelId::default());
            assert_eq!(s.config_hash, 0, "service-factory fleets have no config");
        }
        other => panic!("unexpected reply {other:?}"),
    }

    // unknown ids fail before admission — nothing queued, nothing counted
    let rx = fleet.submit_to(ModelId::new("ghost").unwrap(), ForgetSpec::Class(4), None);
    match rx.recv_timeout(Duration::from_secs(1)).unwrap() {
        Reply::Failed(msg) => assert!(msg.contains("unknown model"), "got: {msg}"),
        other => panic!("expected failure, got {other:?}"),
    }
    assert_eq!(executions_of(&rig, &ForgetSpec::Class(4)), 0);

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.admitted, 1);
    // the per-model rollup has exactly the served model's row
    assert_eq!(stats.per_model.len(), 1);
    assert_eq!(stats.per_model[0].0, ModelId::default());
    assert_eq!(stats.per_model[0].1.served, 1);
}

#[test]
fn fleet_stats_merge_is_queue_stats_merge() {
    // direct arithmetic check on the rollup helper
    let mut a = QueueStats::default();
    a.record(&Timing { queue_ms: 1.0, service_ms: 4.0 }, true);
    let mut b = QueueStats::default();
    b.record(&Timing { queue_ms: 3.0, service_ms: 8.0 }, false);
    let mut merged = QueueStats::default();
    merged.merge(&a);
    merged.merge(&b);
    assert_eq!(merged.served, 1);
    assert_eq!(merged.failures, 1);
    assert_eq!(merged.mean_queue_ms(), 2.0);
    assert_eq!(merged.mean_service_ms(), 6.0);
    assert_eq!(merged.service_hist.count(), 2);
}
