//! Multi-tenant serving end-to-end: two models behind one fleet.
//!
//! These tests run the real pipeline (rn18slim on a small cifar20-like
//! dataset) through [`ModelRegistry`]-backed fleets and pin the four
//! guarantees the registry design makes:
//!
//! 1. **Tenancy**: interleaved forgets against two models with
//!    different `UnlearnConfig`s each come back stamped with their own
//!    model id and config fingerprint, and never coalesce across
//!    tenants.
//! 2. **Copy-on-write**: a registry run is bitwise identical to a
//!    dedicated single-model fleet of the same shape, and *stays*
//!    bitwise identical on repeat requests — the frozen master never
//!    drifts the way a legacy replica's private store does.
//! 3. **Eviction**: a model evicted by the warm-LRU cap re-warms
//!    transparently through the serving path and reproduces its
//!    pre-eviction results bit for bit.
//! 4. **Shared compilation**: worker spin-up is O(1) — graphs compile
//!    once per process on first use, never per worker — and durable
//!    replay routes model-addressed ledger entries through the
//!    registry, mixing tenants in a single claimed batch.

use std::sync::mpsc::Receiver;
use std::sync::Arc;

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::coordinator::wal::{self, Wal};
use ficabu::coordinator::{
    DurabilityConfig, Fleet, FleetConfig, ModelId, ModelRegistry, Reply, Summary, WorkerSpec,
};
use ficabu::data::{cifar20_like, DatasetCfg};
use ficabu::fisher::Importance;
use ficabu::model::ParamStore;
use ficabu::runtime::{Precision, Runtime};
use ficabu::unlearn::{ForgetSpec, Ssd, UnlearnConfig};

/// A real (small) worker spec: rn18slim, deterministic params from
/// `seed`, 4 train / 1 test sample per class.
fn wspec(seed: u64, cfg: UnlearnConfig) -> WorkerSpec {
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let params = ParamStore::init(&meta, seed);
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    let dcfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
    let (train, _) = cifar20_like(&dcfg);
    WorkerSpec {
        meta,
        shared: SharedMeta::builtin(),
        params,
        global,
        train,
        cfg,
        precision: Precision::F32,
    }
}

/// Two tenants with distinct masters *and* distinct serving configs, so
/// their batch keys differ in both the model and the config half.
fn two_tenant_registry() -> (Arc<ModelRegistry>, ModelId, ModelId) {
    let reg = ModelRegistry::new(Runtime::cpu().unwrap());
    let a = ModelId::new("tenant-a").unwrap();
    let b = ModelId::new("tenant-b").unwrap();
    reg.register(a.clone(), wspec(11, UnlearnConfig::default())).unwrap();
    reg.register(b.clone(), wspec(22, Ssd::new(4.0, 0.8).into_config())).unwrap();
    (Arc::new(reg), a, b)
}

fn done(rx: Receiver<Reply>) -> Summary {
    match rx.recv().unwrap() {
        Reply::Done(s) => s,
        other => panic!("expected Done, got {other:?}"),
    }
}

/// Bitwise comparison of everything the unlearning event *computed*
/// (tenancy stamps and measured timing excluded: the former is the
/// address under test elsewhere, the latter is wall-clock).
fn assert_bitwise(a: &Summary, b: &Summary, what: &str) {
    assert_eq!(a.spec, b.spec, "{what}: spec");
    assert_eq!(a.forget_acc.to_bits(), b.forget_acc.to_bits(), "{what}: forget_acc");
    assert_eq!(a.retain_acc.to_bits(), b.retain_acc.to_bits(), "{what}: retain_acc");
    assert_eq!(a.stop_depth, b.stop_depth, "{what}: stop_depth");
    assert_eq!(
        a.macs_vs_ssd_pct.to_bits(),
        b.macs_vs_ssd_pct.to_bits(),
        "{what}: macs_vs_ssd_pct"
    );
    assert_eq!(a.sim_energy_mj.to_bits(), b.sim_energy_mj.to_bits(), "{what}: sim_energy_mj");
    assert_eq!(
        a.sim_energy_vs_ssd_pct.to_bits(),
        b.sim_energy_vs_ssd_pct.to_bits(),
        "{what}: sim_energy_vs_ssd_pct"
    );
    assert_eq!(a.sim_ms.to_bits(), b.sim_ms.to_bits(), "{what}: sim_ms");
    assert_eq!(a.rolled_back, b.rolled_back, "{what}: rolled_back");
}

fn wal_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ficabu_registry_wal_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn two_tenants_interleave_on_one_fleet_without_cross_coalescing() {
    let (reg, a, b) = two_tenant_registry();
    let hash_a = reg.config_hash(&a).unwrap();
    let hash_b = reg.config_hash(&b).unwrap();
    assert_ne!(hash_a, hash_b, "distinct configs must fingerprint apart");

    let fleet = Fleet::start_registry(
        Arc::clone(&reg),
        FleetConfig { workers: 2, queue_cap: 16, ..FleetConfig::default() },
    )
    .unwrap();

    // Interleave the tenants, including the *same* spec for both — the
    // shared spec must stay two entries (two executions), because the
    // batch key carries the model.
    let order = [
        (a.clone(), ForgetSpec::Class(0)),
        (b.clone(), ForgetSpec::Class(0)),
        (a.clone(), ForgetSpec::Class(1)),
        (b.clone(), ForgetSpec::Class(1)),
        (a.clone(), ForgetSpec::Class(9)),
        (b.clone(), ForgetSpec::Class(9)),
    ];
    let rxs: Vec<_> = order
        .iter()
        .map(|(m, s)| fleet.submit_to(m.clone(), s.clone(), None))
        .collect();
    for ((model, spec), rx) in order.iter().zip(rxs) {
        let s = done(rx);
        assert_eq!(&s.model, model, "summary stamps the addressed tenant");
        assert_eq!(s.spec, *spec);
        let want = if *model == a { hash_a } else { hash_b };
        assert_eq!(s.config_hash, want, "summary stamps the tenant's config fingerprint");
    }

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.admitted, 6, "every (model, spec) pair is its own entry");
    assert_eq!(stats.coalesced, 0, "the same spec never coalesces across tenants");
    assert_eq!(stats.per_model.len(), 2, "one rollup row per tenant");
    for (id, q) in &stats.per_model {
        assert_eq!(q.served, 3, "tenant {id} served its three requests");
        assert_eq!(q.failures, 0);
    }
}

#[test]
fn registry_run_is_bitwise_equal_to_a_dedicated_fleet_and_never_drifts() {
    let base = wspec(5, UnlearnConfig::default());

    let reg = ModelRegistry::new(Runtime::cpu().unwrap());
    reg.register(ModelId::default(), base.clone()).unwrap();
    let reg_fleet = Fleet::start_registry(
        Arc::new(reg),
        FleetConfig { workers: 1, ..FleetConfig::default() },
    )
    .unwrap();
    let dedicated =
        Fleet::start(base, FleetConfig { workers: 1, ..FleetConfig::default() }).unwrap();

    // Same worker id, same seed, same master: the CoW overlay must
    // reproduce the dedicated replica's edits bit for bit.
    let s_reg = done(reg_fleet.submit_to(ModelId::default(), ForgetSpec::Class(3), None));
    let s_ded = done(dedicated.submit(ForgetSpec::Class(3)));
    assert_bitwise(&s_reg, &s_ded, "registry vs dedicated");
    assert_eq!(s_reg.model, s_ded.model);
    assert_eq!(s_reg.config_hash, s_ded.config_hash, "both fingerprint the same config");

    // Repeat request on the registry fleet: deltas died with the first
    // summary and the master is frozen, so the answer is identical. (A
    // legacy replica would serve the repeat against its already-edited
    // private store.)
    let s_again = done(reg_fleet.submit_to(ModelId::default(), ForgetSpec::Class(3), None));
    assert_bitwise(&s_reg, &s_again, "repeat on a frozen master");

    reg_fleet.shutdown().unwrap();
    dedicated.shutdown().unwrap();
}

#[test]
fn eviction_and_rewarm_round_trip_through_the_serving_path() {
    let reg = ModelRegistry::new(Runtime::cpu().unwrap()).with_warm_cap(1);
    let a = ModelId::new("tenant-a").unwrap();
    let b = ModelId::new("tenant-b").unwrap();
    reg.register(a.clone(), wspec(11, UnlearnConfig::default())).unwrap();
    reg.register(b.clone(), wspec(22, UnlearnConfig::default())).unwrap();
    let reg = Arc::new(reg);

    let fleet = Fleet::start_registry(
        Arc::clone(&reg),
        FleetConfig { workers: 1, ..FleetConfig::default() },
    )
    .unwrap();

    let warm_flags = |reg: &ModelRegistry| -> Vec<bool> {
        reg.list().iter().map(|m| m.warm).collect() // sorted by id: [a, b]
    };

    let first = done(fleet.submit_to(a.clone(), ForgetSpec::Class(1), None));
    assert_eq!(reg.builds(), 1);
    assert_eq!(warm_flags(&reg), [true, false]);

    // Serving b exceeds the warm cap of 1 and evicts a.
    done(fleet.submit_to(b.clone(), ForgetSpec::Class(1), None));
    assert_eq!(reg.builds(), 2);
    assert_eq!(warm_flags(&reg), [false, true]);

    // Serving a again re-warms it through the normal path — and because
    // the master is frozen, the rebuilt graph answers bit for bit what
    // the evicted one did.
    let again = done(fleet.submit_to(a.clone(), ForgetSpec::Class(1), None));
    assert_eq!(reg.builds(), 3, "re-warm is a counted rebuild");
    assert_eq!(warm_flags(&reg), [true, false]);
    assert_bitwise(&first, &again, "pre- vs post-eviction");

    fleet.shutdown().unwrap();
}

#[test]
fn worker_spinup_never_rebuilds_shared_graphs() {
    let (reg, a, b) = two_tenant_registry();
    let fleet = Fleet::start_registry(
        Arc::clone(&reg),
        FleetConfig { workers: 4, queue_cap: 16, ..FleetConfig::default() },
    )
    .unwrap();
    assert_eq!(reg.builds(), 0, "spinning up 4 workers compiles nothing");

    let rxs: Vec<_> = (0..4usize)
        .map(|c| {
            let m = if c % 2 == 0 { a.clone() } else { b.clone() };
            fleet.submit_to(m, ForgetSpec::Class(c), None)
        })
        .collect();
    for rx in rxs {
        done(rx);
    }
    fleet.shutdown().unwrap();
    assert_eq!(
        reg.builds(),
        2,
        "4 workers x 2 models compile exactly once per model, not per worker"
    );
}

#[test]
fn durable_replay_routes_tenants_and_mixes_them_in_one_claim() {
    let dir = wal_dir("replay");
    let (reg, a, b) = two_tenant_registry();
    let hash_a = reg.config_hash(&a).unwrap();
    let hash_b = reg.config_hash(&b).unwrap();

    // Run 1 creates the ledger, serves nothing, shuts down clean.
    Fleet::start_registry_durable(
        Arc::clone(&reg),
        FleetConfig { workers: 1, ..FleetConfig::default() },
        DurabilityConfig { dir: dir.clone(), checkpoint_every: 8 },
    )
    .unwrap()
    .shutdown()
    .unwrap();

    // Simulate a crash after admission: accepted records with no
    // completions. The same spec appears for both tenants (two distinct
    // batch keys) and twice for tenant-a (one key — recovery dedups).
    {
        let (w, _tail) = Wal::open_append(dir.join(wal::LEDGER_FILE)).unwrap();
        w.append_accepted(&a, &ForgetSpec::Class(7), hash_a, None).unwrap();
        w.append_accepted(&b, &ForgetSpec::Class(7), hash_b, None).unwrap();
        w.append_accepted(&a, &ForgetSpec::Class(7), hash_a, None).unwrap();
    }

    // Run 2: replay pre-seeds the queue before the single worker
    // spawns, so its first pass claims both tenants' entries in one
    // lock acquisition — a mixed batch.
    let fleet = Fleet::start_registry_durable(
        Arc::clone(&reg),
        FleetConfig { workers: 1, batch_max: 4, ..FleetConfig::default() },
        DurabilityConfig { dir: dir.clone(), checkpoint_every: 8 },
    )
    .unwrap();
    let stats = fleet.shutdown().unwrap();

    let dur = stats.durability.expect("durable fleet reports ledger counters");
    assert_eq!(dur.replayed, 2, "3 accepted records, 2 batch keys");
    assert_eq!(stats.admitted, 2);
    assert_eq!(stats.merged().max_batch, 2, "one claim took both tenants");
    assert_eq!(stats.per_model.len(), 2);
    for (id, q) in &stats.per_model {
        assert_eq!(q.served, 1, "tenant {id} replayed exactly once");
    }

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ledger_addressing_an_unregistered_model_fails_startup_loudly() {
    let dir = wal_dir("unknown");
    let reg = ModelRegistry::new(Runtime::cpu().unwrap());
    let a = ModelId::new("tenant-a").unwrap();
    reg.register(a.clone(), wspec(11, UnlearnConfig::default())).unwrap();
    let reg = Arc::new(reg);

    Fleet::start_registry_durable(
        Arc::clone(&reg),
        FleetConfig { workers: 1, ..FleetConfig::default() },
        DurabilityConfig { dir: dir.clone(), checkpoint_every: 8 },
    )
    .unwrap()
    .shutdown()
    .unwrap();
    {
        let (w, _tail) = Wal::open_append(dir.join(wal::LEDGER_FILE)).unwrap();
        w.append_accepted(&ModelId::new("tenant-b").unwrap(), &ForgetSpec::Class(2), 0, None)
            .unwrap();
    }

    let err = Fleet::start_registry_durable(
        Arc::clone(&reg),
        FleetConfig { workers: 1, ..FleetConfig::default() },
        DurabilityConfig { dir: dir.clone(), checkpoint_every: 8 },
    )
    .err()
    .expect("an unroutable ledger must refuse startup");
    let msg = format!("{err:#}");
    assert!(msg.contains("tenant-b"), "error names the model: {msg}");
    assert!(msg.contains("not registered"), "error says why: {msg}");

    let _ = std::fs::remove_dir_all(&dir);
}
