//! Property tests for the true-int8 execution path: the tiled int8
//! GEMM core and fused int8 conv against a scalar
//! quantize -> integer-accumulate -> requantize oracle (bitwise —
//! integer accumulation is order-free and the quantization expressions
//! are shared), thread-count bitwise determinism for the i8 kernel,
//! and closeness to the f32 reference. The end-to-end int8-served
//! unlearning test lives in its own binary (`tests/int8_e2e.rs`): it
//! mutates `FICABU_ARTIFACTS`, and environment-mutating tests get a
//! dedicated process (see `tests/gemm_threads_env.rs`).

use ficabu::runtime::cpu::gemm;
use ficabu::runtime::cpu::kernels::{self, naive, Conv};
use ficabu::runtime::cpu::scratch::Scratch;
use ficabu::tensor::quant::QTensor;
use ficabu::tensor::Tensor;
use ficabu::util::prng::Pcg32;

/// Randomized shapes that exercise every tiling edge: M/N/K not
/// divisible by MR/NR/KC, odd k (the pair kernel's zero pad row), k=1,
/// single-row/column operands, and k spanning multiple KC blocks.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 1),
    (3, 1, 5),
    (4, 8, 8),
    (5, 9, 7),
    (8, 64, 8),
    (13, 17, 11),
    (64, 64, 64),
    (33, 129, 65),
    (100, 37, 129),
    (257, 96, 35),
    (30, 600, 20),
    (9, 1025, 40),
];

fn qweight(rng: &mut Pcg32, k: usize, n: usize) -> QTensor {
    QTensor::from_weight(&Tensor::new(vec![k, n], rng.normal_vec(k * n, 0.5)).unwrap())
}

#[test]
fn tiled_int8_matmul_matches_scalar_oracle_bitwise() {
    let mut rng = Pcg32::seeded(0x18a);
    let mut sc = Scratch::new();
    for &(m, k, n) in SHAPES {
        let x = rng.normal_vec(m * k, 1.0);
        let wq = qweight(&mut rng, k, n);
        let want = naive::matmul_i8(&x, &wq.data, &wq.scales, m, k, n);
        let mut got = vec![0.0f32; m * n];
        kernels::matmul_i8_into(&mut sc, &x, &wq, m, k, n, &mut got);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "int8 matmul {m}x{k}x{n} diverges from the oracle at [{i}]: {g} vs {w}"
            );
        }
    }
}

#[test]
fn fused_int8_conv_matches_scalar_oracle_bitwise() {
    // (kh, kw, cin, cout, stride, b, h, w) — 1x1 kernels, strides,
    // non-square spatial dims, multi-batch, odd patch dims
    let cases = [
        (1, 1, 1, 1, 1, 1, 2, 2),
        (1, 1, 3, 8, 1, 2, 5, 5),
        (1, 1, 4, 4, 2, 1, 8, 8),
        (3, 3, 1, 1, 1, 1, 3, 3),
        (3, 3, 2, 3, 1, 2, 7, 5),
        (3, 3, 3, 8, 2, 1, 9, 9),
        (5, 5, 2, 2, 1, 1, 6, 6),
    ];
    let mut rng = Pcg32::seeded(0x18b);
    let mut sc = Scratch::new();
    for &(kh, kw, cin, cout, stride, b, h, w) in &cases {
        let cv = Conv { kh, kw, cin, cout, stride };
        let x = rng.normal_vec(b * h * w * cin, 1.0);
        let wq = QTensor::from_weight(
            &Tensor::new(vec![kh, kw, cin, cout], rng.normal_vec(kh * kw * cin * cout, 0.5))
                .unwrap(),
        );
        let want = naive::conv_fwd_i8(&cv, &x, &wq.data, &wq.scales, b, h, w);
        let (ho, wo) = cv.out_hw(h, w);
        let mut got = vec![0.0f32; b * ho * wo * cout];
        cv.fwd_i8_into(&mut sc, &x, &wq, b, h, w, &mut got);
        for (i, (g, want_v)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                want_v.to_bits(),
                "int8 conv {kh}x{kw} s{stride} {cin}->{cout} diverges at [{i}]"
            );
        }
    }
}

#[test]
fn int8_thread_count_does_not_change_results() {
    // big enough to clear the fork threshold
    let (m, k, n) = (192, 1100, 96);
    let mut rng = Pcg32::seeded(0x18c);
    let x = rng.normal_vec(m * k, 1.0);
    let wq = qweight(&mut rng, k, n);
    let a_scale = ficabu::tensor::quant::scale_for(&x);
    let mut sc = Scratch::new();
    let av = gemm::QuantStrided { data: &x, rs: k, cs: 1, inv_scale: 1.0 / a_scale };
    let bv = gemm::QStrided { data: &wq.data, rs: n, cs: 1 };
    let mut y1 = vec![0.0f32; m * n];
    gemm::gemm_i8_threads(&mut sc, &av, &bv, a_scale, &wq.scales, m, k, n, &mut y1, 1);
    for threads in [2usize, 3, 4, 7] {
        let mut yt = vec![0.0f32; m * n];
        gemm::gemm_i8_threads(&mut sc, &av, &bv, a_scale, &wq.scales, m, k, n, &mut yt, threads);
        for (i, (u, v)) in y1.iter().zip(&yt).enumerate() {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "threads={threads} diverges at [{i}]: {u} vs {v}"
            );
        }
    }
}

#[test]
fn int8_matmul_tracks_f32_reference() {
    // quantization error bound sanity: int8 result vs the f32 product
    // of the dequantized weight
    let (m, k, n) = (40, 96, 24);
    let mut rng = Pcg32::seeded(0x18d);
    let mut sc = Scratch::new();
    let x = rng.normal_vec(m * k, 1.0);
    let wq = qweight(&mut rng, k, n);
    let wf = wq.dequantize();
    let mut f32_out = vec![0.0f32; m * n];
    gemm::matmul_into(&mut sc, &x, &wf.data, m, k, n, &mut f32_out);
    let mut i8_out = vec![0.0f32; m * n];
    kernels::matmul_i8_into(&mut sc, &x, &wq, m, k, n, &mut i8_out);
    let num: f32 = f32_out
        .iter()
        .zip(&i8_out)
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    let den: f32 = f32_out.iter().map(|v| v * v).sum();
    let rel = (num / den.max(1e-12)).sqrt();
    assert!(rel < 0.05, "int8 vs f32 relative L2 error {rel}");
}
