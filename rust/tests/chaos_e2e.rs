//! Chaos end-to-end tests: deterministic fault plans
//! (`testkit::faults`) driven through the real engine and fleet.
//!
//! Covers the transactional-unlearning guarantee (a mid-pass error or
//! panic leaves the replica's `ParamStore` bitwise identical to its
//! pre-request state, f32 masters and int8 copies alike) and the fleet
//! acceptance path: panic mid-dampen → `Reply::Failed` (no hung or
//! dropped receivers) → worker respawn → retried request `Done`.
//!
//! The fault plan is process-global, so every test here serializes on
//! one lock and clears the plan before releasing it.

use std::io::Write;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::coordinator::{
    checkpoint, wal, DurabilityConfig, Fleet, FleetConfig, ModelId, Pacing, Reply, Summary,
    UnlearnService, UnlearnSession, WorkerSpec,
};
use ficabu::data::{cifar20_like, Dataset, DatasetCfg};
use ficabu::fisher::Importance;
use ficabu::metrics;
use ficabu::model::{Model, ParamStore};
use ficabu::runtime::{Precision, Runtime};
use ficabu::testkit::faults;
use ficabu::unlearn::{ForgetSpec, Ssd};

static CHAOS: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    CHAOS.lock().unwrap_or_else(PoisonError::into_inner)
}

fn train_set() -> Dataset {
    let cfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
    cifar20_like(&cfg).0
}

/// Session over an untrained builtin model. `int8` additionally deploys
/// the store's true-int8 copies and serves forward/eval in int8.
fn session(seed: u64, int8: bool) -> UnlearnSession {
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let model = Model::load(&rt, meta.clone()).unwrap();
    let mut params = ParamStore::init(&meta, seed);
    if int8 {
        params.quantize_int8(&meta);
    }
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    let precision = if int8 { Precision::Int8 } else { Precision::F32 };
    UnlearnSession::builder()
        .model(model)
        .params(params)
        .global(global)
        .train(train_set())
        .config(Ssd::new(1.0, 1.0).into_config().with_precision(precision))
        .seed(seed)
        .build()
        .unwrap()
}

/// FNV-1a-style fingerprint over the store's f32 bit patterns.
fn fingerprint(params: &ParamStore) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for t in params.flat() {
        for v in &t.data {
            h ^= u64::from(v.to_bits());
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Bitwise store equality: f32 masters and (when present) the int8
/// copies' dequantized values.
fn assert_store_bitwise_eq(a: &ParamStore, b: &ParamStore) {
    let (fa, fb) = (a.flat(), b.flat());
    assert_eq!(fa.len(), fb.len());
    for (ta, tb) in fa.iter().zip(&fb) {
        assert_eq!(ta.data.len(), tb.data.len());
        assert!(
            ta.data.iter().zip(&tb.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "f32 masters differ"
        );
    }
    assert_eq!(a.is_quantized(), b.is_quantized());
    for k in 0..a.seg.len() {
        match (a.qseg(k), b.qseg(k)) {
            (None, None) => {}
            (Some(qa), Some(qb)) => {
                for (sa, sb) in qa.iter().zip(qb) {
                    match (sa, sb) {
                        (None, None) => {}
                        (Some(qta), Some(qtb)) => {
                            let (da, db) = (qta.dequantize().data, qtb.dequantize().data);
                            assert!(
                                da.iter().zip(&db).all(|(x, y)| x.to_bits() == y.to_bits()),
                                "int8 copies differ in segment {k}"
                            );
                        }
                        _ => panic!("quantized slot shape differs in segment {k}"),
                    }
                }
            }
            _ => panic!("quantization state differs in segment {k}"),
        }
    }
}

/// Mid-pass injected error: the event fails, and the replica is bitwise
/// back to its pre-request parameters — accuracy readouts included.
fn mid_pass_error_rolls_back_bitwise(int8: bool) {
    let mut s = session(42, int8);
    let pristine = s.params.clone();
    let pool = s.train.class_indices(3);
    let forget_before =
        metrics::eval_accuracy(&s.model, &s.params, &s.train, &pool).unwrap();

    // Depths 1 and 2 dampen (journaling their pre-images); depth 3 errors.
    faults::arm("dampen:3:error").unwrap();
    let err = s.forget(&ForgetSpec::Class(3)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected fault"), "got: {msg}");
    assert!(msg.contains("rolled back"), "got: {msg}");
    assert_eq!(faults::hits("dampen"), 3, "fault plan was exercised");
    faults::clear();

    assert_store_bitwise_eq(&pristine, &s.params);
    let forget_after =
        metrics::eval_accuracy(&s.model, &s.params, &s.train, &pool).unwrap();
    assert_eq!(forget_before, forget_after, "rollback preserves the accuracy readout");

    // The rolled-back replica still serves: the same request now succeeds
    // and reports a clean (non-rolled-back) summary.
    let sm = s.forget(&ForgetSpec::Class(3)).unwrap();
    assert!(!sm.rolled_back);
}

#[test]
fn mid_pass_error_rolls_back_bitwise_f32() {
    let _g = serial();
    faults::clear();
    mid_pass_error_rolls_back_bitwise(false);
}

#[test]
fn mid_pass_error_rolls_back_bitwise_int8() {
    let _g = serial();
    faults::clear();
    mid_pass_error_rolls_back_bitwise(true);
}

/// Fleet worker wrapper that fingerprints its replica's parameters
/// after every request — panic or not — so the test can observe the
/// rollback from outside the worker thread.
struct Probe {
    inner: UnlearnSession,
    log: Arc<Mutex<Vec<u64>>>,
}

impl UnlearnService for Probe {
    fn unlearn(&mut self, spec: &ForgetSpec) -> anyhow::Result<Summary> {
        let out = catch_unwind(AssertUnwindSafe(|| self.inner.forget(spec)));
        self.log.lock().unwrap().push(fingerprint(&self.inner.params));
        match out {
            Ok(r) => r,
            Err(payload) => resume_unwind(payload),
        }
    }
}

#[test]
fn fleet_survives_a_panic_mid_dampen() {
    let _g = serial();
    faults::clear();

    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    let wspec = WorkerSpec {
        meta: meta.clone(),
        shared: SharedMeta::builtin(),
        params: ParamStore::init(&meta, 5),
        global,
        train: train_set(),
        cfg: Ssd::new(1.0, 1.0).into_config(),
        precision: Precision::F32,
    };
    // Fingerprint log: one entry per replica build (from the factory)
    // and one per served request (from the probe).
    let log: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let log2 = Arc::clone(&log);
    let fleet = Fleet::start_with(
        FleetConfig {
            workers: 1,
            queue_cap: 8,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
        move |wid| {
            let inner = UnlearnSession::from_spec(&wspec, wid)?;
            log2.lock().unwrap().push(fingerprint(&inner.params));
            Ok(Probe { inner, log: Arc::clone(&log2) })
        },
    )
    .unwrap();

    // The 2nd dampened segment of the first request panics.
    faults::arm("dampen:2:panic").unwrap();
    let rx = fleet.submit(ForgetSpec::Class(3));
    match rx.recv().unwrap() {
        Reply::Failed(msg) => {
            assert!(msg.contains("panicked"), "got: {msg}");
            assert!(msg.contains("injected fault"), "got: {msg}");
        }
        other => panic!("expected failure, got {other:?}"),
    }

    // Retry after the respawn: the one-shot fault already fired, so the
    // same request now completes on the fresh replica.
    let rx = fleet.submit(ForgetSpec::Class(3));
    match rx.recv().unwrap() {
        Reply::Done(sm) => {
            assert_eq!(sm.spec, ForgetSpec::Class(3));
            assert!(!sm.rolled_back);
        }
        other => panic!("retry: unexpected reply {other:?}"),
    }
    faults::clear();

    // [build 0, post-panic, build 1 (respawn), post-done]
    let fps = log.lock().unwrap().clone();
    assert_eq!(fps.len(), 4, "2 builds + 2 served requests, got {fps:?}");
    assert_eq!(fps[1], fps[0], "panicked request rolled back bitwise");
    assert_eq!(fps[2], fps[0], "respawned replica rebuilds the same params");
    assert_ne!(fps[3], fps[0], "the successful event edits parameters");

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.alive, 1);
    let total = stats.merged();
    assert_eq!(total.panics, 1);
    assert_eq!(total.respawns, 1);
    assert_eq!(total.served, 1);
    assert_eq!(total.failures, 1);
}

// --- durability ---------------------------------------------------------

fn durable_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("ficabu_chaos_wal_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn durable_wspec(seed: u64) -> WorkerSpec {
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    WorkerSpec {
        meta: meta.clone(),
        shared: SharedMeta::builtin(),
        params: ParamStore::init(&meta, seed),
        global,
        train: train_set(),
        cfg: Ssd::new(1.0, 1.0).into_config(),
        precision: Precision::F32,
    }
}

/// Durable production fleet, checkpointing every completion.
fn durable_fleet_n(dir: &Path, workers: usize) -> Fleet {
    Fleet::start_durable(
        durable_wspec(5),
        FleetConfig {
            workers,
            queue_cap: 8,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
        DurabilityConfig { dir: dir.to_path_buf(), checkpoint_every: 1 },
    )
    .unwrap()
}

/// One-worker durable production fleet, checkpointing every completion.
fn durable_fleet(dir: &Path) -> Fleet {
    durable_fleet_n(dir, 1)
}

/// Replayed entries have no reply channel; poll the rollup instead.
fn wait_served(fleet: &Fleet, n: u64) {
    let t0 = Instant::now();
    while fleet.stats().merged().served < n {
        assert!(t0.elapsed() < Duration::from_secs(120), "replayed work never completed");
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// The headline durability guarantee: kill the process after a request
/// is accepted (fsync'd) but before it is served — leaving a torn frame
/// behind for good measure — restart, and the recovered store ends
/// bitwise identical to a run that was never interrupted.
#[test]
fn kill_and_restart_replays_to_the_uninterrupted_store() {
    let _g = serial();
    faults::clear();
    let dir_a = durable_dir("reference");
    let dir_b = durable_dir("crashed");
    let spec1 = ForgetSpec::Class(3);
    let spec2 = ForgetSpec::Classes(vec![1, 4]);

    // Reference run: both events, no interruption.
    {
        let fleet = durable_fleet(&dir_a);
        for spec in [&spec1, &spec2] {
            match fleet.submit(spec.clone()).recv().unwrap() {
                Reply::Done(sm) => assert!(!sm.rolled_back),
                other => panic!("reference {spec}: unexpected reply {other:?}"),
            }
        }
        let stats = fleet.shutdown().unwrap();
        assert_eq!(stats.durability.unwrap().checkpoints, 2);
    }

    // Crashed run: the first event completes; the second is accepted on
    // disk but the process "dies" before serving it. The crash is
    // simulated exactly as a kill would leave the ledger: an `Accepted`
    // record with no completion, then a torn half-written frame.
    {
        let fleet = durable_fleet(&dir_b);
        match fleet.submit(spec1.clone()).recv().unwrap() {
            Reply::Done(_) => {}
            other => panic!("crashed run, event 1: unexpected reply {other:?}"),
        }
        fleet.shutdown().unwrap();

        let ledger = dir_b.join(wal::LEDGER_FILE);
        let (w, _tail) = wal::Wal::open_append(&ledger).unwrap();
        w.append_accepted(&ModelId::default(), &spec2, 0, None).unwrap();
        drop(w);
        let mut f = std::fs::OpenOptions::new().append(true).open(&ledger).unwrap();
        // frame header promising 64 payload bytes, followed by 3
        f.write_all(&[64, 0, 0, 0, 0xde, 0xad, 0xbe, 0xef, 1, 2, 3]).unwrap();
    }

    // Restart: the torn tail is dropped, the unserved event replays.
    {
        let fleet = durable_fleet(&dir_b);
        assert_eq!(fleet.stats().durability.unwrap().replayed, 1);
        wait_served(&fleet, 1);
        let stats = fleet.shutdown().unwrap();
        assert_eq!(stats.merged().served, 1);
        assert_eq!(stats.merged().failures, 0);
    }

    let a = checkpoint::load_latest(&dir_a).unwrap().expect("reference checkpoint");
    let b = checkpoint::load_latest(&dir_b).unwrap().expect("recovered checkpoint");
    assert_store_bitwise_eq(&a.params, &b.params);

    // The rewritten ledger carries the replayed completion with a real
    // post-edit accuracy readout (failed entries log the -1 sentinel
    // instead), proof the unlearning pass actually ran after recovery.
    let scan = wal::read_ledger(&dir_b.join(wal::LEDGER_FILE)).unwrap();
    assert!(!scan.truncated);
    let done: Vec<(u64, f64)> = scan
        .records
        .iter()
        .filter_map(|r| match r {
            wal::Record::Completed {
                seq,
                disposition: wal::Disposition::Done,
                forget_acc,
                ..
            } => Some((*seq, *forget_acc)),
            _ => None,
        })
        .collect();
    assert_eq!(done.len(), 1, "exactly the replayed event completed, got {done:?}");
    assert!(
        (0.0..=1.0).contains(&done[0].1),
        "replayed event ledgers a real accuracy readout, got {}",
        done[0].1
    );

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

/// A crash *during* checkpointing must never surface partial state: the
/// loader skips garbage and torn files and lands on the last checkpoint
/// that was fully written, and recovery replays what it left uncovered.
#[test]
fn interrupted_checkpoint_never_loads_partial_state() {
    let _g = serial();
    faults::clear();
    let dir = durable_dir("ckpt_crash");

    {
        let fleet = durable_fleet(&dir);
        match fleet.submit(ForgetSpec::Class(2)).recv().unwrap() {
            Reply::Done(_) => {}
            other => panic!("event 1: unexpected reply {other:?}"),
        }
        // Every checkpoint attempt from here on dies mid-write —
        // including the final one at shutdown.
        faults::arm("checkpoint:every1:error").unwrap();
        match fleet.submit(ForgetSpec::Class(7)).recv().unwrap() {
            // the pass itself commits; only its checkpoint is lost
            Reply::Done(sm) => assert!(!sm.rolled_back),
            other => panic!("event 2: unexpected reply {other:?}"),
        }
        let stats = fleet.shutdown().unwrap();
        faults::clear();
        assert_eq!(stats.durability.unwrap().checkpoints, 1, "only checkpoint 1 landed");
    }

    // Adversarial debris, as an interrupted writer would leave behind:
    // a lexicographically-newer checkpoint full of garbage and a torn
    // tempfile.
    std::fs::write(dir.join("ckpt-0000000001-0000000099.fcp"), b"FICABUC1 but not really")
        .unwrap();
    std::fs::write(dir.join("ckpt-0000000001-0000000100.fcp.tmp"), [0u8; 7]).unwrap();

    // The loader lands on the last fully-written checkpoint.
    let ck = checkpoint::load_latest(&dir).unwrap().expect("valid checkpoint survives");
    assert_eq!((ck.generation, ck.covering_seq), (1, 1));

    // Restart: the completion the failed checkpoint left uncovered
    // (seq 2) replays on top of the surviving state and the recovered
    // fleet checkpoints again under the bumped generation.
    let fleet = durable_fleet(&dir);
    assert_eq!(fleet.stats().durability.unwrap().replayed, 1);
    wait_served(&fleet, 1);
    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.durability.unwrap().checkpoints, 1);
    let ck = checkpoint::load_latest(&dir).unwrap().expect("post-recovery checkpoint");
    assert_eq!(ck.generation, 2);

    let _ = std::fs::remove_dir_all(&dir);
}

/// A `Done` completion whose ledger append fails taints the replica:
/// its store holds an edit the ledger will replay, so writing another
/// checkpoint from it would get that pass applied twice. The fleet must
/// stop checkpointing (including the final flush), let recovery replay
/// the unledgered entry onto the *last good* checkpoint, and still end
/// bitwise identical to an uninterrupted run.
#[test]
fn failed_completion_append_taints_the_checkpoint_and_replays() {
    let _g = serial();
    faults::clear();
    let dir_a = durable_dir("taint_reference");
    let dir_b = durable_dir("taint_crashed");
    let spec1 = ForgetSpec::Class(3);
    let spec2 = ForgetSpec::Class(7);

    // Reference run: both events, no interruption.
    {
        let fleet = durable_fleet(&dir_a);
        for spec in [&spec1, &spec2] {
            match fleet.submit(spec.clone()).recv().unwrap() {
                Reply::Done(sm) => assert!(!sm.rolled_back),
                other => panic!("reference {spec}: unexpected reply {other:?}"),
            }
        }
        let stats = fleet.shutdown().unwrap();
        assert_eq!(stats.durability.unwrap().checkpoints, 2);
    }

    // Tainted run: event 1 lands cleanly (checkpoint 1). For event 2
    // the *second* ledger append after arming fails — hit 1 is its
    // `Accepted` record (must succeed: the request needs its slot), hit
    // 2 is its `Done` completion. The pass itself commits and the
    // caller is answered, but the completion never reaches disk.
    {
        let fleet = durable_fleet(&dir_b);
        match fleet.submit(spec1.clone()).recv().unwrap() {
            Reply::Done(_) => {}
            other => panic!("tainted run, event 1: unexpected reply {other:?}"),
        }
        faults::arm("wal_append:2:error").unwrap();
        match fleet.submit(spec2.clone()).recv().unwrap() {
            Reply::Done(sm) => {
                assert!(!sm.rolled_back);
                assert_eq!(sm.wal_seq, Some(2));
            }
            other => panic!("tainted run, event 2: unexpected reply {other:?}"),
        }
        let stats = fleet.shutdown().unwrap();
        faults::clear();
        // checkpoint_every = 1, yet neither event 2's cadence checkpoint
        // nor the final shutdown flush ran: the replica is tainted.
        assert_eq!(stats.durability.unwrap().checkpoints, 1);
    }

    // The surviving checkpoint covers exactly event 1.
    let ck = checkpoint::load_latest(&dir_b).unwrap().expect("last good checkpoint");
    assert_eq!((ck.generation, ck.covering_seq), (1, 1));

    // Restart: event 2 is accepted-without-completed on disk, so it
    // replays onto the last good checkpoint — once, not twice.
    {
        let fleet = durable_fleet(&dir_b);
        assert_eq!(fleet.stats().durability.unwrap().replayed, 1);
        wait_served(&fleet, 1);
        let stats = fleet.shutdown().unwrap();
        assert_eq!(stats.merged().served, 1);
        assert_eq!(stats.durability.unwrap().checkpoints, 1);
    }

    let a = checkpoint::load_latest(&dir_a).unwrap().expect("reference checkpoint");
    let b = checkpoint::load_latest(&dir_b).unwrap().expect("recovered checkpoint");
    assert_store_bitwise_eq(&a.params, &b.params);

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

/// With several replicas drifting independently no single store covers
/// the ledger, so a multi-worker durable fleet must never write a
/// checkpoint — recovery replays the full ledger instead.
#[test]
fn multi_worker_durable_fleet_never_checkpoints_and_replays_everything() {
    let _g = serial();
    faults::clear();
    let dir = durable_dir("multiworker");

    {
        let fleet = durable_fleet_n(&dir, 2);
        for spec in [ForgetSpec::Class(1), ForgetSpec::Class(4)] {
            match fleet.submit(spec.clone()).recv().unwrap() {
                Reply::Done(sm) => assert!(!sm.rolled_back),
                other => panic!("{spec}: unexpected reply {other:?}"),
            }
        }
        let stats = fleet.shutdown().unwrap();
        // checkpoint_every = 1 and two clean completions, yet no
        // checkpoint: cadence and final flush are both workers==1 only.
        assert_eq!(stats.durability.unwrap().checkpoints, 0);
    }
    assert!(checkpoint::load_latest(&dir).unwrap().is_none(), "no checkpoint on disk");

    // Restart: with no checkpoint the covering scope is empty, so every
    // `Done` entry in the ledger replays.
    {
        let fleet = durable_fleet_n(&dir, 2);
        assert_eq!(fleet.stats().durability.unwrap().replayed, 2);
        wait_served(&fleet, 2);
        let stats = fleet.shutdown().unwrap();
        assert_eq!(stats.merged().served, 2);
        assert_eq!(stats.durability.unwrap().checkpoints, 0);
    }

    let _ = std::fs::remove_dir_all(&dir);
}
