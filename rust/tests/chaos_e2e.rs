//! Chaos end-to-end tests: deterministic fault plans
//! (`testkit::faults`) driven through the real engine and fleet.
//!
//! Covers the transactional-unlearning guarantee (a mid-pass error or
//! panic leaves the replica's `ParamStore` bitwise identical to its
//! pre-request state, f32 masters and int8 copies alike) and the fleet
//! acceptance path: panic mid-dampen → `Reply::Failed` (no hung or
//! dropped receivers) → worker respawn → retried request `Done`.
//!
//! The fault plan is process-global, so every test here serializes on
//! one lock and clears the plan before releasing it.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use ficabu::config::{ModelMeta, SharedMeta};
use ficabu::coordinator::{
    Fleet, FleetConfig, Pacing, Reply, Summary, UnlearnService, UnlearnSession, WorkerSpec,
};
use ficabu::data::{cifar20_like, Dataset, DatasetCfg};
use ficabu::fisher::Importance;
use ficabu::metrics;
use ficabu::model::{Model, ParamStore};
use ficabu::runtime::{Precision, Runtime};
use ficabu::testkit::faults;
use ficabu::unlearn::{ForgetSpec, Ssd};

static CHAOS: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    CHAOS.lock().unwrap_or_else(PoisonError::into_inner)
}

fn train_set() -> Dataset {
    let cfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
    cifar20_like(&cfg).0
}

/// Session over an untrained builtin model. `int8` additionally deploys
/// the store's true-int8 copies and serves forward/eval in int8.
fn session(seed: u64, int8: bool) -> UnlearnSession {
    let rt = Runtime::cpu().unwrap();
    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let model = Model::load(&rt, meta.clone()).unwrap();
    let mut params = ParamStore::init(&meta, seed);
    if int8 {
        params.quantize_int8(&meta);
    }
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    let precision = if int8 { Precision::Int8 } else { Precision::F32 };
    UnlearnSession::builder()
        .model(model)
        .params(params)
        .global(global)
        .train(train_set())
        .config(Ssd::new(1.0, 1.0).into_config().with_precision(precision))
        .seed(seed)
        .build()
        .unwrap()
}

/// FNV-1a-style fingerprint over the store's f32 bit patterns.
fn fingerprint(params: &ParamStore) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for t in params.flat() {
        for v in &t.data {
            h ^= u64::from(v.to_bits());
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Bitwise store equality: f32 masters and (when present) the int8
/// copies' dequantized values.
fn assert_store_bitwise_eq(a: &ParamStore, b: &ParamStore) {
    let (fa, fb) = (a.flat(), b.flat());
    assert_eq!(fa.len(), fb.len());
    for (ta, tb) in fa.iter().zip(&fb) {
        assert_eq!(ta.data.len(), tb.data.len());
        assert!(
            ta.data.iter().zip(&tb.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "f32 masters differ"
        );
    }
    assert_eq!(a.is_quantized(), b.is_quantized());
    for k in 0..a.seg.len() {
        match (a.qseg(k), b.qseg(k)) {
            (None, None) => {}
            (Some(qa), Some(qb)) => {
                for (sa, sb) in qa.iter().zip(qb) {
                    match (sa, sb) {
                        (None, None) => {}
                        (Some(qta), Some(qtb)) => {
                            let (da, db) = (qta.dequantize().data, qtb.dequantize().data);
                            assert!(
                                da.iter().zip(&db).all(|(x, y)| x.to_bits() == y.to_bits()),
                                "int8 copies differ in segment {k}"
                            );
                        }
                        _ => panic!("quantized slot shape differs in segment {k}"),
                    }
                }
            }
            _ => panic!("quantization state differs in segment {k}"),
        }
    }
}

/// Mid-pass injected error: the event fails, and the replica is bitwise
/// back to its pre-request parameters — accuracy readouts included.
fn mid_pass_error_rolls_back_bitwise(int8: bool) {
    let mut s = session(42, int8);
    let pristine = s.params.clone();
    let pool = s.train.class_indices(3);
    let forget_before =
        metrics::eval_accuracy(&s.model, &s.params, &s.train, &pool).unwrap();

    // Depths 1 and 2 dampen (journaling their pre-images); depth 3 errors.
    faults::arm("dampen:3:error").unwrap();
    let err = s.forget(&ForgetSpec::Class(3)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected fault"), "got: {msg}");
    assert!(msg.contains("rolled back"), "got: {msg}");
    assert_eq!(faults::hits("dampen"), 3, "fault plan was exercised");
    faults::clear();

    assert_store_bitwise_eq(&pristine, &s.params);
    let forget_after =
        metrics::eval_accuracy(&s.model, &s.params, &s.train, &pool).unwrap();
    assert_eq!(forget_before, forget_after, "rollback preserves the accuracy readout");

    // The rolled-back replica still serves: the same request now succeeds
    // and reports a clean (non-rolled-back) summary.
    let sm = s.forget(&ForgetSpec::Class(3)).unwrap();
    assert!(!sm.rolled_back);
}

#[test]
fn mid_pass_error_rolls_back_bitwise_f32() {
    let _g = serial();
    faults::clear();
    mid_pass_error_rolls_back_bitwise(false);
}

#[test]
fn mid_pass_error_rolls_back_bitwise_int8() {
    let _g = serial();
    faults::clear();
    mid_pass_error_rolls_back_bitwise(true);
}

/// Fleet worker wrapper that fingerprints its replica's parameters
/// after every request — panic or not — so the test can observe the
/// rollback from outside the worker thread.
struct Probe {
    inner: UnlearnSession,
    log: Arc<Mutex<Vec<u64>>>,
}

impl UnlearnService for Probe {
    fn unlearn(&mut self, spec: &ForgetSpec) -> anyhow::Result<Summary> {
        let out = catch_unwind(AssertUnwindSafe(|| self.inner.forget(spec)));
        self.log.lock().unwrap().push(fingerprint(&self.inner.params));
        match out {
            Ok(r) => r,
            Err(payload) => resume_unwind(payload),
        }
    }
}

#[test]
fn fleet_survives_a_panic_mid_dampen() {
    let _g = serial();
    faults::clear();

    let meta = ModelMeta::builtin("rn18slim").unwrap();
    let mut global = Importance::zeros_like(&meta);
    global.floor(1e-6);
    let wspec = WorkerSpec {
        meta: meta.clone(),
        shared: SharedMeta::builtin(),
        params: ParamStore::init(&meta, 5),
        global,
        train: train_set(),
        cfg: Ssd::new(1.0, 1.0).into_config(),
        precision: Precision::F32,
    };
    // Fingerprint log: one entry per replica build (from the factory)
    // and one per served request (from the probe).
    let log: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let log2 = Arc::clone(&log);
    let fleet = Fleet::start_with(
        FleetConfig {
            workers: 1,
            queue_cap: 8,
            deadline: None,
            batch_max: 1,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
        move |wid| {
            let inner = UnlearnSession::from_spec(&wspec, wid)?;
            log2.lock().unwrap().push(fingerprint(&inner.params));
            Ok(Probe { inner, log: Arc::clone(&log2) })
        },
    )
    .unwrap();

    // The 2nd dampened segment of the first request panics.
    faults::arm("dampen:2:panic").unwrap();
    let rx = fleet.submit(ForgetSpec::Class(3));
    match rx.recv().unwrap() {
        Reply::Failed(msg) => {
            assert!(msg.contains("panicked"), "got: {msg}");
            assert!(msg.contains("injected fault"), "got: {msg}");
        }
        other => panic!("expected failure, got {other:?}"),
    }

    // Retry after the respawn: the one-shot fault already fired, so the
    // same request now completes on the fresh replica.
    let rx = fleet.submit(ForgetSpec::Class(3));
    match rx.recv().unwrap() {
        Reply::Done(sm) => {
            assert_eq!(sm.spec, ForgetSpec::Class(3));
            assert!(!sm.rolled_back);
        }
        other => panic!("retry: unexpected reply {other:?}"),
    }
    faults::clear();

    // [build 0, post-panic, build 1 (respawn), post-done]
    let fps = log.lock().unwrap().clone();
    assert_eq!(fps.len(), 4, "2 builds + 2 served requests, got {fps:?}");
    assert_eq!(fps[1], fps[0], "panicked request rolled back bitwise");
    assert_eq!(fps[2], fps[0], "respawned replica rebuilds the same params");
    assert_ne!(fps[3], fps[0], "the successful event edits parameters");

    let stats = fleet.shutdown().unwrap();
    assert_eq!(stats.alive, 1);
    let total = stats.merged();
    assert_eq!(total.panics, 1);
    assert_eq!(total.respawns, 1);
    assert_eq!(total.served, 1);
    assert_eq!(total.failures, 1);
}
