#!/usr/bin/env python3
"""Validate and compare the repo's BENCH_*.json artifacts.

Subcommands
-----------
validate FILE
    Schema check (suite/git_rev/threads/cases, per-case name/iters/
    min_ms/mean_ms, unique names) plus per-suite guardrails:

    * suite "runtime": the INT8 guardrail that used to live inline in
      ci.yml — tiled-int8 GEMM and fused-int8 conv cases must exist, and
      at the largest shape benched in both precisions the int8 GEMM must
      not be slower than the f32 tiled GEMM.
    * suite "serve": paced 1-worker and 4-worker arms and the
      paced-speedup-4v1 case must exist, and the speedup must clear
      --min-speedup (default 1.5 — conservative for small CI runners;
      the acceptance target on dev boxes is >= 2x). At least one
      serve/spec-* arm (ForgetSpec diversity through the fleet) must
      exist and cover all three spec shapes. The HTTP front-end must
      stay benched: a serve/http-loopback/workers=* socket arm plus the
      parse-lazy / parse-tree pair, with the lazy path scanner within a
      25% noise margin of the full tree parser on min_ms. A serve/chaos-*
      arm must exist, must actually have injected faults (failed and
      respawns > 0), and must keep >= 50% of the fault-free paced
      4-worker arm's rps. A serve/wal-paced/* arm (write-ahead ledger +
      checkpoints on) must exist, must actually have ledgered (wal_seq
      > 0), and must keep >= 80% of the fault-free paced 4-worker arm's
      rps. A serve/audited-paced/* arm (hash-chained audit log + MIA
      attestation riding every completion) must exist, must actually
      have attested (attested > 0, chain_len > 0), and must keep >= 90%
      of the fault-free paced 4-worker arm's rps.
      A serve/multi-tenant/workers=* arm (model registry) must
      exist with graph_builds <= models (workers share Arc'd compiled
      graphs — no per-worker rebuild), and a
      serve/registry-spinup/workers=* arm must exist with
      graph_builds_at_start == 0 (starting registry workers compiles
      nothing).

compare BASELINE CURRENT
    Fail when any case present in both files regressed by more than
    --max-regress-pct on min_ms (default 25%), with an absolute floor
    (--abs-floor-ms) so sub-jitter cases cannot trip the gate. A missing
    BASELINE file is tolerated (first run on a branch has no baseline).
    Host-bound serving arms and the training-prepare case are skipped:
    their wall time is dominated by shared-runner noise, not by the code
    under test.
"""

import argparse
import json
import os
import sys

# compare(): prefixes whose min_ms is runner-noise dominated.
NOISY_PREFIXES = (
    "serve/host/",
    "serve/coalesce-burst",
    "serve/spec-",
    "serve/chaos-",
    "serve/wal-paced",
    "serve/audited-paced",
    "serve/registry-spinup",
    "prepare ",
)


def _fail(msg):
    print(f"FAIL: {msg}")
    sys.exit(1)


def _load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        _fail(f"{path}: {e}")


def _cases_by_name(doc, path):
    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        _fail(f"{path}: 'cases' must be a non-empty list")
    out = {}
    for c in cases:
        for key in ("name", "iters", "min_ms", "mean_ms"):
            if key not in c:
                _fail(f"{path}: case {c.get('name', '?')!r} missing {key!r}")
        if not isinstance(c["min_ms"], (int, float)) or c["min_ms"] < 0:
            _fail(f"{path}: case {c['name']!r} has bad min_ms {c['min_ms']!r}")
        if c["name"] in out:
            _fail(f"{path}: duplicate case name {c['name']!r}")
        out[c["name"]] = c
    return out


def _check_runtime(cases, path):
    """INT8 guardrail (moved verbatim in spirit from the old inline step)."""
    int8 = [n for n in cases if n.startswith("gemm/tiled-int8/")]
    if not int8:
        _fail(f"{path}: no gemm/tiled-int8/ cases")
    if not any(n.startswith("conv/fused-int8/") for n in cases):
        _fail(f"{path}: no conv/fused-int8/ case")
    # compare at the largest shape benched in BOTH precisions, so the
    # check holds under any preset's shape list
    shared = [
        (cases["gemm/tiled/" + shape]["min_ms"], shape)
        for shape in (n[len("gemm/tiled-int8/"):] for n in int8)
        if "gemm/tiled/" + shape in cases
    ]
    if not shared:
        _fail(f"{path}: no GEMM shape benched in both f32 and int8")
    f32_ms, shape = max(shared)
    i8_ms = cases["gemm/tiled-int8/" + shape]["min_ms"]
    if i8_ms > f32_ms:
        _fail(
            f"{path}: int8 tiled GEMM slower than f32 at {shape!r}: "
            f"{i8_ms:.3f} vs {f32_ms:.3f} ms"
        )
    print(
        f"int8 guardrail OK at {shape!r}: {f32_ms:.3f} ms f32 vs "
        f"{i8_ms:.3f} ms int8 ({f32_ms / max(i8_ms, 1e-9):.2f}x)"
    )


def _check_serve(cases, path, min_speedup):
    for name in ("serve/paced/workers=1", "serve/paced/workers=4",
                 "serve/paced-speedup-4v1"):
        if name not in cases:
            _fail(f"{path}: missing case {name!r}")
    speedup = cases["serve/paced-speedup-4v1"].get("speedup")
    if not isinstance(speedup, (int, float)):
        _fail(f"{path}: paced-speedup-4v1 case has no 'speedup' field")
    if speedup < min_speedup:
        _fail(
            f"{path}: paced 4-worker speedup {speedup:.2f}x below the "
            f"{min_speedup:.2f}x gate"
        )
    # spec-diversity arms: the ForgetSpec grammar must stay benched
    spec_arms = [n for n in cases if n.startswith("serve/spec-")]
    if not spec_arms:
        _fail(f"{path}: no serve/spec-* arm (ForgetSpec diversity unbenched)")
    mix = cases.get("serve/spec-mix")
    if mix is None:
        _fail(f"{path}: missing case 'serve/spec-mix'")
    for field in ("class_replies", "classes_replies", "samples_replies"):
        if not isinstance(mix.get(field), (int, float)) or mix[field] <= 0:
            _fail(
                f"{path}: serve/spec-mix must serve every spec shape "
                f"({field} = {mix.get(field)!r})"
            )
    # HTTP front-end arms: the wire path and its parsing split must stay
    # benched — a socket arm over loopback plus the lazy-vs-tree pair
    if not any(n.startswith("serve/http-loopback/workers=") for n in cases):
        _fail(f"{path}: no serve/http-loopback/workers=* arm "
              "(HTTP front-end unbenched)")
    for name in ("serve/http-loopback/parse-lazy",
                 "serve/http-loopback/parse-tree"):
        if name not in cases:
            _fail(f"{path}: missing case {name!r}")
    # Tolerance matches the compare gate's 25%: on smoke presets and
    # noisy shared runners these microbenchmark minima can jitter past
    # each other, so only a clear inversion fails (on dev boxes the
    # scanner is ~an order of magnitude ahead, nowhere near the margin).
    lazy = cases["serve/http-loopback/parse-lazy"]["min_ms"]
    tree = cases["serve/http-loopback/parse-tree"]["min_ms"]
    if lazy > tree * 1.25:
        _fail(
            f"{path}: lazy path scan ({lazy:.3f} ms) clearly slower than the "
            f"full tree parse ({tree:.3f} ms, +25% margin) — laziness "
            "stopped paying"
        )
    # chaos arm: supervision must stay benched, and a fleet absorbing
    # injected panics (plus the respawns they cost) must keep at least
    # half the fault-free paced arm's throughput
    chaos_arms = [n for n in cases if n.startswith("serve/chaos-")]
    if not chaos_arms:
        _fail(f"{path}: no serve/chaos-* arm (panic supervision unbenched)")
    chaos = cases[chaos_arms[0]]
    chaos_rps = chaos.get("rps")
    if not isinstance(chaos_rps, (int, float)) or chaos_rps <= 0:
        _fail(f"{path}: {chaos_arms[0]!r} has no positive 'rps' field")
    for field in ("failed", "respawns"):
        if not isinstance(chaos.get(field), (int, float)) or chaos[field] <= 0:
            _fail(
                f"{path}: {chaos_arms[0]!r} injected no faults "
                f"({field} = {chaos.get(field)!r}) — the chaos arm ran fault-free"
            )
    paced_rps = cases["serve/paced/workers=4"].get("rps")
    if not isinstance(paced_rps, (int, float)) or paced_rps <= 0:
        _fail(f"{path}: serve/paced/workers=4 has no positive 'rps' field")
    if chaos_rps < 0.5 * paced_rps:
        _fail(
            f"{path}: chaos throughput {chaos_rps:.3f} rps below half the "
            f"fault-free paced arm ({paced_rps:.3f} rps) — respawns are "
            "eating the fleet"
        )
    # durability arm: the write-ahead ledger must stay benched, must
    # actually ledger, and fsync-per-request must ride the paced
    # envelope rather than dominate it
    wal_arms = [n for n in cases if n.startswith("serve/wal-paced")]
    if not wal_arms:
        _fail(f"{path}: no serve/wal-paced* arm (durability unbenched)")
    wal = cases[wal_arms[0]]
    wal_rps = wal.get("rps")
    if not isinstance(wal_rps, (int, float)) or wal_rps <= 0:
        _fail(f"{path}: {wal_arms[0]!r} has no positive 'rps' field")
    if not isinstance(wal.get("wal_seq"), (int, float)) or wal["wal_seq"] <= 0:
        _fail(
            f"{path}: {wal_arms[0]!r} ledgered nothing "
            f"(wal_seq = {wal.get('wal_seq')!r}) — the durable arm ran dry"
        )
    if wal_rps < 0.8 * paced_rps:
        _fail(
            f"{path}: durable throughput {wal_rps:.3f} rps below 80% of the "
            f"fault-free paced arm ({paced_rps:.3f} rps) — the ledger fsyncs "
            "are dominating the paced envelope"
        )
    # audited arm: the hash-chained audit log and the per-forget MIA
    # attestation probes must stay benched, must actually attest, and
    # must ride the paced envelope (>= 90% of fault-free throughput —
    # the probes are O(eval), the chain append is one fsync'd frame)
    audited_arms = [n for n in cases if n.startswith("serve/audited-paced")]
    if not audited_arms:
        _fail(f"{path}: no serve/audited-paced* arm (audit chain unbenched)")
    audited = cases[audited_arms[0]]
    audited_rps = audited.get("rps")
    if not isinstance(audited_rps, (int, float)) or audited_rps <= 0:
        _fail(f"{path}: {audited_arms[0]!r} has no positive 'rps' field")
    for field in ("attested", "chain_len"):
        if not isinstance(audited.get(field), (int, float)) or audited[field] <= 0:
            _fail(
                f"{path}: {audited_arms[0]!r} recorded no audit evidence "
                f"({field} = {audited.get(field)!r}) — the audited arm ran dry"
            )
    if audited_rps < 0.9 * paced_rps:
        _fail(
            f"{path}: audited throughput {audited_rps:.3f} rps below 90% of "
            f"the fault-free paced arm ({paced_rps:.3f} rps) — the audit "
            "chain or the attestation probes are dominating the envelope"
        )
    # multi-tenant arm: the model registry must stay benched — several
    # models behind one fleet with compiled graphs Arc-shared (builds
    # bounded by the model count, no matter how many workers serve), and
    # registry worker spin-up must stay O(1) (the spin-up case compiles
    # nothing)
    mt_arms = [n for n in cases if n.startswith("serve/multi-tenant/workers=")]
    if not mt_arms:
        _fail(f"{path}: no serve/multi-tenant/workers=* arm "
              "(model registry unbenched)")
    mt = cases[mt_arms[0]]
    models = mt.get("models")
    builds = mt.get("graph_builds")
    if not isinstance(models, (int, float)) or models < 2:
        _fail(f"{path}: {mt_arms[0]!r} must host >= 2 models "
              f"(models = {models!r})")
    if not isinstance(builds, (int, float)) or builds <= 0:
        _fail(f"{path}: {mt_arms[0]!r} has no positive 'graph_builds' field")
    if builds > models:
        _fail(
            f"{path}: {mt_arms[0]!r} rebuilt shared graphs: {builds:.0f} "
            f"builds for {models:.0f} models — workers must share the "
            "registry's compiled graphs, not rebuild per worker"
        )
    spin_arms = [n for n in cases
                 if n.startswith("serve/registry-spinup/workers=")]
    if not spin_arms:
        _fail(f"{path}: no serve/registry-spinup/workers=* arm "
              "(registry worker spin-up unbenched)")
    spin = cases[spin_arms[0]]
    if spin.get("graph_builds_at_start") != 0:
        _fail(
            f"{path}: {spin_arms[0]!r} compiled during spin-up "
            f"(graph_builds_at_start = {spin.get('graph_builds_at_start')!r}) "
            "— registry worker startup must not build graphs"
        )
    print(
        f"serve guardrail OK: paced 4v1 speedup {speedup:.2f}x, "
        f"{len(spec_arms)} spec arm(s), lazy scan "
        f"{tree / max(lazy, 1e-9):.1f}x faster than tree parse, "
        f"chaos at {chaos_rps / paced_rps:.2f}x, durable at "
        f"{wal_rps / paced_rps:.2f}x, and audited at "
        f"{audited_rps / paced_rps:.2f}x of fault-free throughput "
        f"({audited['attested']:.0f} attested link(s)), "
        f"{models:.0f}-model registry at {builds:.0f} graph build(s)"
    )


def cmd_validate(args):
    doc = _load(args.file)
    for key in ("suite", "git_rev", "threads", "cases"):
        if key not in doc:
            _fail(f"{args.file}: missing top-level key {key!r}")
    cases = _cases_by_name(doc, args.file)
    suite = doc["suite"]
    if suite == "runtime":
        _check_runtime(cases, args.file)
    elif suite == "serve":
        _check_serve(cases, args.file, args.min_speedup)
    else:
        # a renamed suite must not silently disable its guardrails
        _fail(f"{args.file}: unknown suite {suite!r} (expected runtime|serve)")
    print(
        f"OK: {args.file}: suite {suite!r} rev {doc['git_rev']} "
        f"threads {doc['threads']} with {len(cases)} cases"
    )


def cmd_compare(args):
    if not os.path.exists(args.baseline):
        print(
            f"NOTE: baseline {args.baseline} not found — tolerating "
            "(first run on this branch has no baseline artifact)"
        )
        return
    base = _cases_by_name(_load(args.baseline), args.baseline)
    cur = _cases_by_name(_load(args.current), args.current)
    shared = 0
    skipped = 0
    regressions = []
    for name, c in cur.items():
        b = base.get(name)
        if b is None:
            continue
        if name.startswith(NOISY_PREFIXES):
            skipped += 1
            continue
        shared += 1
        limit = b["min_ms"] * (1.0 + args.max_regress_pct / 100.0)
        if c["min_ms"] > limit and c["min_ms"] - b["min_ms"] > args.abs_floor_ms:
            regressions.append(
                f"  {name}: {b['min_ms']:.3f} ms -> {c['min_ms']:.3f} ms "
                f"(+{100.0 * (c['min_ms'] / b['min_ms'] - 1.0):.1f}%)"
            )
    print(
        f"compared {shared} shared cases ({skipped} noisy skipped, "
        f"{len(cur) - shared - skipped} new) against {args.baseline}"
    )
    if regressions:
        print(f"FAIL: {len(regressions)} case(s) regressed more than "
              f"{args.max_regress_pct:.0f}% on min_ms:")
        for r in regressions:
            print(r)
        sys.exit(1)
    print("regression gate OK")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    v = sub.add_parser("validate", help="schema + per-suite guardrails")
    v.add_argument("file")
    v.add_argument("--min-speedup", type=float, default=1.5,
                   help="serve suite: minimum paced 4v1 speedup (default 1.5)")
    v.set_defaults(fn=cmd_validate)

    c = sub.add_parser("compare", help="min_ms regression gate vs a baseline")
    c.add_argument("baseline")
    c.add_argument("current")
    c.add_argument("--max-regress-pct", type=float, default=25.0)
    c.add_argument("--abs-floor-ms", type=float, default=0.25,
                   help="ignore regressions smaller than this many ms")
    c.set_defaults(fn=cmd_compare)

    args = p.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
